"""First-class network topology (core/topology.py): validation, the
star/chain/tree constructors, per-edge bandwidth (closed-form AND measured,
summing to the existing Table-I totals for the star), and the multi-hop
graph execution behind the Scheme API —

  * `topology=star(J)` (and None) leaves every existing path bit-identical;
  * an edge-homogeneous dense chain reproduces the star's latents and
    trajectory BIT-identically (hops re-code on the same quantizer grid);
  * heterogeneous per-edge `link_bits` ({2, 8} on a 3-node chain) meters
    per-edge measured bytes == per-edge closed forms exactly;
  * chain/tree INL trains end-to-end on the fixture; FL/SL validate and
    reject non-star graphs;
  * sharded graph rounds match single-device at rtol 1e-4 (forced
    2-device CI leg).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _schemes_common import BATCH, CFG, fixture_data, trajectory

from repro.core import bandwidth, schemes, wirefmt
from repro.core import topology as T
from repro.core.schemes import runner

CHAIN = T.chain(CFG.num_clients)
ROUNDS = 4


# ---------------------------------------------------------------------------
# Construction + validation
# ---------------------------------------------------------------------------

def test_constructors_shape():
    s = T.star(5)
    assert s.num_views() == 5 and s.is_default_star()
    assert [e.key for e in s.topo_edges()] == \
        [f"m{j}->fuse" for j in range(5)]
    assert all(len(s.payload(e)) == 1 for e in s.edges)

    c = T.chain(5)
    assert c.num_views() == 5 and not c.is_default_star()
    assert c.payload(c.topo_edges()[-1]) == (0, 1, 2, 3, 4)
    assert len(c.levels()) == 5                   # a line: one node a level

    tr = T.tree(2, 2)
    assert tr.num_views() == 6
    assert len(tr.levels()) == 2                  # 4 leaves, then 2 relays
    assert sorted(len(tr.payload(e)) for e in tr.edges) == [1, 1, 1, 1, 3, 3]


@pytest.mark.parametrize("bad,match", [
    # no fuse node
    (lambda: T.Topology((T.Node("a", "measure"),), ()), "exactly ONE fuse"),
    # two fuse nodes
    (lambda: T.Topology((T.Node("f", "fuse"), T.Node("g", "fuse")), ()),
     "exactly ONE fuse"),
    # multicast: two outgoing edges
    (lambda: T.Topology(
        (T.Node("a", "measure"), T.Node("r", "relay"), T.Node("f", "fuse")),
        (T.Edge("a", "r"), T.Edge("a", "f"), T.Edge("r", "f"))),
     "two outgoing"),
    # cycle between relays
    (lambda: T.Topology(
        (T.Node("a", "measure"), T.Node("r1", "relay"),
         T.Node("r2", "relay"), T.Node("f", "fuse")),
        (T.Edge("a", "r1"), T.Edge("r1", "r2"), T.Edge("r2", "r1"))),
     "cycle|reach"),
    # dead end: measure node with no route
    (lambda: T.Topology(
        (T.Node("a", "measure"), T.Node("f", "fuse")), ()),
     "cannot reach"),
    # relay that receives nothing
    (lambda: T.Topology(
        (T.Node("r", "relay"), T.Node("f", "fuse")), (T.Edge("r", "f"),)),
     "receives nothing"),
    # measure node with an incoming edge
    (lambda: T.Topology(
        (T.Node("a", "measure"), T.Node("b", "measure"),
         T.Node("f", "fuse")),
        (T.Edge("a", "b"), T.Edge("b", "f"))),
     "incoming"),
    # unknown node
    (lambda: T.Topology((T.Node("f", "fuse"),), (T.Edge("x", "f"),)),
     "unknown node"),
    # bad role
    (lambda: T.Topology((T.Node("a", "router"), T.Node("f", "fuse")),
                        (T.Edge("a", "f"),)), "unknown role"),
])
def test_validation_rejects(bad, match):
    with pytest.raises(ValueError, match=match):
        bad()


def test_validation_errors_name_the_offenders():
    """The messages are actionable: they carry the exact node/edge that is
    wrong, not just the rule that was broken."""
    with pytest.raises(ValueError, match=r"\['dup'\]"):
        T.Topology((T.Node("dup", "measure"), T.Node("dup", "measure"),
                    T.Node("f", "fuse")),
                   (T.Edge("dup", "f"),))
    with pytest.raises(ValueError, match=r"ghost->f.*\['ghost'\]"):
        T.Topology((T.Node("f", "fuse"),), (T.Edge("ghost", "f"),))
    with pytest.raises(ValueError, match="'a'.*two outgoing.*a->r.*a->f"):
        T.Topology(
            (T.Node("a", "measure"), T.Node("r", "relay"),
             T.Node("f", "fuse")),
            (T.Edge("a", "r"), T.Edge("a", "f"), T.Edge("r", "f")))
    with pytest.raises(ValueError, match="'stranded'.*dead-ends at 'loner'"):
        T.Topology(
            (T.Node("stranded", "measure"), T.Node("loner", "relay"),
             T.Node("m", "measure"), T.Node("f", "fuse")),
            (T.Edge("stranded", "loner"), T.Edge("m", "f")))
    with pytest.raises(ValueError, match="'orphan' receives nothing"):
        T.Topology((T.Node("orphan", "relay"), T.Node("f", "fuse")),
                   (T.Edge("orphan", "f"),))


def test_resolution_against_cfg():
    assert T.resolve(None, CFG) == T.star(CFG.num_clients)
    assert T.nontrivial(None, CFG) is None
    assert T.nontrivial(T.star(CFG.num_clients), CFG) is None
    assert T.nontrivial(CHAIN, CFG) is CHAIN
    # cfg.topology is the fallback the explicit argument overrides
    cfg_c = dataclasses.replace(CFG, topology=CHAIN)
    assert T.nontrivial(None, cfg_c) is CHAIN
    with pytest.raises(ValueError, match="view nodes"):
        T.resolve(T.chain(3), CFG)
    with pytest.raises(ValueError, match="star topology only"):
        T.require_star(CHAIN, CFG, scheme="fl")
    T.require_star(T.star(CFG.num_clients), CFG, scheme="fl")   # fine


# ---------------------------------------------------------------------------
# Per-edge bandwidth: closed forms and measured bytes
# ---------------------------------------------------------------------------

def test_star_edges_sum_to_table1_totals_exactly():
    """star(J)'s per-edge ledger reproduces the existing §III-C totals —
    closed-form AND measured, for every wire format."""
    p = CFG.num_clients * CFG.d_bottleneck
    edges = T.round_edge_bits(T.star(CFG.num_clients), CFG, BATCH)
    assert sum(edges.values()) == bandwidth.inl_epoch_bits(
        p, BATCH * CFG.num_clients, CFG.num_clients, CFG.link_bits)

    cfg8 = dataclasses.replace(CFG, link_bits=8)
    for wire in ("dense", "packed", "packed_duplex"):
        per_edge = T.round_edge_wire_bytes(T.star(CFG.num_clients), cfg8,
                                           BATCH, wire=wire)
        legacy = wirefmt.round_wire_bytes(
            CFG.num_clients * BATCH, CFG.d_bottleneck, link_bits=8,
            wire=wire)["total"]
        assert sum(per_edge.values()) == legacy


def test_chain_edges_charge_their_payload():
    edges = T.round_edge_bits(CHAIN, CFG, BATCH)
    base = 2 * BATCH * CFG.d_bottleneck * CFG.link_bits
    assert list(edges.values()) == [base * k
                                    for k in range(1, CFG.num_clients + 1)]


def test_heterogeneous_chain_measured_equals_closed_forms():
    """The satellite contract: a 3-node chain (2 view nodes -> fuse) with
    per-edge bits {2, 8} meters per-edge MEASURED bytes == per-edge closed
    forms under the packed_duplex wire (both directions at the edge's
    width), at a lane-filling d_bottleneck."""
    cfg = dataclasses.replace(CFG, num_clients=2, noise_stds=(0.4, 1.0),
                              d_bottleneck=16)
    topo = T.chain(2, link_bits=(2, 8))
    closed = T.round_edge_bits(topo, cfg, BATCH)
    measured = T.round_edge_wire_bytes(topo, cfg, BATCH,
                                       wire="packed_duplex")
    assert set(closed) == {"m0->r1", "r1->fuse"}
    assert closed["m0->r1"] == 2 * BATCH * 1 * 16 * 2
    assert closed["r1->fuse"] == 2 * BATCH * 2 * 16 * 8
    for k in closed:
        assert measured[k] * 8 == closed[k], k
    # and the totals the Scheme API reports are these sums
    s_inl = schemes.get("inl")
    assert s_inl.bits_per_round(cfg, None, BATCH, topology=topo) == \
        sum(closed.values())
    assert s_inl.wire_bytes_per_round(cfg, None, BATCH,
                                      wire="packed_duplex",
                                      topology=topo) == \
        sum(measured.values())


def test_meter_edge_ledger_sums_to_totals():
    m = bandwidth.BandwidthMeter()
    m.add_edge("a->b", bits=8.0, nbytes=1.0)
    m.add_edge("b->f", bits=16.0, nbytes=2.0)
    m.add_edge("a->b", bits=8.0, nbytes=1.0)
    assert m.edge_bits == {"a->b": 16.0, "b->f": 16.0}
    assert m.edge_measured_bytes == {"a->b": 2.0, "b->f": 2.0}
    assert m.total_bits == 32.0 and m.measured_bytes == 4.0


def test_table1_rejects_unknown_network():
    with pytest.raises(ValueError, match="unknown Table-I network"):
        bandwidth.table1(50_000, "alexnet")
    assert bandwidth.table1(50_000, "vgg16")["federated"] > 0


# ---------------------------------------------------------------------------
# Graph execution
# ---------------------------------------------------------------------------

def _latents(J=5, B=8, d=8, bits=32):
    k = jax.random.PRNGKey(0)
    mu = jax.random.normal(k, (J, B, d))
    lv = jnp.full((J, B, d), -1.0)
    eps = jax.random.normal(jax.random.PRNGKey(1), (J, B, d))
    return mu, lv, eps


def test_homogeneous_chain_is_bitwise_the_star():
    """Re-coding on the same quantizer grid is the identity, so a dense
    edge-homogeneous chain delivers the star's latents bit for bit."""
    mu, lv, eps = _latents()
    cfg8 = dataclasses.replace(CFG, link_bits=8)
    for cfg in (CFG, cfg8):
        u_s, r_s, uf_s = T.graph_cut_and_ship(T.star(5), cfg, mu, lv, eps)
        u_c, r_c, uf_c = T.graph_cut_and_ship(T.chain(5), cfg, mu, lv, eps)
        np.testing.assert_array_equal(np.asarray(uf_s), np.asarray(uf_c))
        np.testing.assert_array_equal(np.asarray(r_s), np.asarray(r_c))


def test_heterogeneous_first_hops_quantize_per_edge():
    """Each node's own latent is cut at ITS outgoing edge's width, and a
    coarser downstream hop re-codes everything it forwards."""
    from repro.kernels import ops, ref
    mu, lv, eps = _latents(J=2)
    cfg = dataclasses.replace(CFG, num_clients=2, noise_stds=(0.4, 1.0))
    topo = T.chain(2, link_bits=(8, 2))
    u, rate, uf = T.graph_cut_and_ship(topo, cfg, mu, lv, eps)
    u8, _ = ops.cutlayer(mu, lv, eps, link_bits=8)
    u2, _ = ops.cutlayer(mu, lv, eps, link_bits=2)
    # node 0 cuts at 8 bits; its latent is then re-coded to the 2-bit grid
    # by the r1->fuse hop; node 1 cuts at 2 bits (already on that grid)
    np.testing.assert_array_equal(np.asarray(u[0]), np.asarray(u8[0]))
    np.testing.assert_array_equal(np.asarray(u[1]), np.asarray(u2[1]))
    np.testing.assert_array_equal(
        np.asarray(uf[0]), np.asarray(ref.quantize_value(u8[0], 2)))
    np.testing.assert_array_equal(np.asarray(uf[1]), np.asarray(u2[1]))
    # a genuinely different grid than cutting at 2 bits directly would give
    assert float(jnp.abs(uf[0] - u2[0]).max()) >= 0.0


def test_graph_backward_routes_error_chunks():
    """AD through the hops: every node still receives a finite error chunk
    (edge-reversed routing), on homogeneous and heterogeneous graphs."""
    mu, lv, eps = _latents()
    for topo, cfg in [(T.chain(5), CFG),
                      (T.chain(5, link_bits=(2, 4, 8, 8, 32)), CFG)]:
        def f(m):
            u, r, uf = T.graph_cut_and_ship(topo, cfg, m, lv, eps)
            return jnp.sum(uf ** 2) + jnp.sum(r)
        g = jax.grad(f)(mu)
        assert bool(jnp.isfinite(g).all())
        assert float(jnp.abs(g).max()) > 0.0


# ---------------------------------------------------------------------------
# The Scheme API on topologies
# ---------------------------------------------------------------------------

def _inl_trajectory(cfg, topo, wire="dense", rounds=ROUNDS):
    views, labels = fixture_data()
    scheme = schemes.get("inl")
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    round_fn = scheme.make_round(cfg, wire=wire, topology=topo)
    v = views[None, :, :BATCH]
    lab = labels[None, :BATCH]
    losses = []
    for i in range(rounds):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    return losses, state


def test_explicit_star_is_bitwise_the_default():
    """topology=star(J) (and cfg.topology=star) dispatch the legacy path —
    the golden trajectories cannot move."""
    want = trajectory("inl")["losses"][:ROUNDS]
    got, _ = _inl_trajectory(CFG, T.star(CFG.num_clients))
    assert list(want) == got
    got_cfg, _ = _inl_trajectory(
        dataclasses.replace(CFG, topology=T.star(CFG.num_clients)), None)
    assert list(want) == got_cfg


def test_dense_chain_trajectory_is_bitwise_the_star():
    want = trajectory("inl")["losses"][:ROUNDS]
    got, state = _inl_trajectory(CFG, CHAIN)
    assert list(want) == got
    # at full-precision links (the fixture's link_bits=32, every hop the
    # identity) predict through the chain matches the star bit for bit
    views, labels = fixture_data()
    scheme = schemes.get("inl")
    p_star = scheme.predict(state, views[:, :BATCH])
    p_chain = scheme.predict(state, views[:, :BATCH], topology=CHAIN,
                             cfg=CFG)
    np.testing.assert_array_equal(np.asarray(p_star), np.asarray(p_chain))


def test_graph_predict_models_quantized_delivery():
    """The documented convention split (core/inl.predict): the star ships
    UNQUANTIZED latents at inference (seed behaviour, golden-pinned) while
    the graph path delivers what the narrow links actually carry — at
    2-bit links the two visibly differ, and the graph result equals
    decoding the re-quantized latents directly."""
    from repro.core import inl as inl_lib
    cfg2 = dataclasses.replace(CFG, link_bits=2)
    _, state = _inl_trajectory(CFG, None, rounds=2)
    views, _ = fixture_data()
    scheme = schemes.get("inl")
    p_star = scheme.predict(state, views[:, :BATCH])          # unquantized
    p_chain = scheme.predict(state, views[:, :BATCH],
                             topology=T.chain(CFG.num_clients), cfg=cfg2)
    assert float(jnp.abs(p_star - p_chain).max()) > 1e-4
    # the graph delivery == cut at 2 bits, every hop idempotent after that
    params, mstate = state["params"], state["state"]
    (mu, lv), _ = inl_lib._encode_mu_logvar(params, mstate,
                                            views[:, :BATCH], train=False)
    from repro.kernels import ref
    u2 = ref.quantize_value(mu, 2)
    joint, _ = inl_lib.decode(params, u2, train=False)
    np.testing.assert_allclose(np.asarray(p_chain),
                               np.asarray(jax.nn.softmax(joint, -1)),
                               atol=1e-6)


def test_tree_and_heterogeneous_chain_train_end_to_end():
    from repro.data import multiview
    cfg6 = dataclasses.replace(
        CFG, num_clients=6, noise_stds=(0.4, 1.0, 2.0, 3.0, 4.0, 0.7))
    imgs, labels6 = multiview.make_base_dataset(
        128, image_shape=CFG.image_shape, seed=0)
    views6 = jnp.asarray(multiview.make_views(imgs, cfg6.noise_stds))
    scheme = schemes.get("inl")
    state = scheme.init(cfg6, jax.random.PRNGKey(0))
    round_fn = scheme.make_round(cfg6, topology=T.tree(2, 2))
    v, lab = views6[None, :, :BATCH], jnp.asarray(labels6)[None, :BATCH]
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    het = T.chain(CFG.num_clients, link_bits=(2, 4, 8, 8, 32))
    losses, _ = _inl_trajectory(CFG, het)
    assert losses[-1] < losses[0], losses


def test_runner_meters_per_edge_and_totals_agree():
    views, labels = fixture_data()
    views, labels = np.asarray(views[:, :64]), np.asarray(labels[:64])
    meter = bandwidth.BandwidthMeter()
    curve = runner.run_scheme("inl", views, labels, CFG, epochs=2,
                              batch_size=16, eval_n=32, topology=CHAIN,
                              meter=meter)
    assert set(meter.edge_bits) == {e.key for e in CHAIN.edges}
    assert sum(meter.edge_bits.values()) == meter.total_bits
    assert sum(meter.edge_measured_bytes.values()) == meter.measured_bytes
    assert curve[-1].gbits == meter.total_bits / bandwidth.GBIT
    # dense 32-bit links: measured == accounted per edge, not just in total
    for k, bits in meter.edge_bits.items():
        assert meter.edge_measured_bytes[k] * 8 == bits
    # the star run reproduces the pre-topology curve with a per-edge ledger
    m_star = bandwidth.BandwidthMeter()
    c_star = runner.run_scheme("inl", views, labels, CFG, epochs=2,
                               batch_size=16, eval_n=32, meter=m_star)
    c_legacy = runner.run_scheme("inl", views, labels, CFG, epochs=2,
                                 batch_size=16, eval_n=32)
    assert [p.gbits for p in c_star] == [p.gbits for p in c_legacy]
    assert len(m_star.edge_bits) == CFG.num_clients


@pytest.mark.parametrize("name", ["fl", "sl"])
def test_star_only_schemes_reject_graphs(name):
    scheme = schemes.get(name)
    with pytest.raises(ValueError, match="star topology only"):
        scheme.make_round(CFG, topology=CHAIN)
    with pytest.raises(ValueError, match="star topology only"):
        scheme.bits_per_round(CFG, None, BATCH, topology=CHAIN)
    # the explicit star is fine
    assert scheme.bits_per_round(CFG, trajectory(name)["state"], BATCH,
                                 topology=T.star(CFG.num_clients)) > 0


# ---------------------------------------------------------------------------
# Sharded graph execution (forced 2-device CI leg)
# ---------------------------------------------------------------------------

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=2")


def _sharded_trajectory(cfg, topo, mesh, views, labels, wire="dense"):
    scheme = schemes.get("inl")
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    state = jax.device_put(state, scheme.state_shardings(cfg, state, mesh))
    round_fn = scheme.make_sharded_round(cfg, mesh, wire=wire,
                                         topology=topo)
    v = views[None, :, :BATCH]
    lab = labels[None, :BATCH]
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    return losses


@multi_device
def test_sharded_chain_matches_single_device():
    """Graph rounds on the ('client','data') mesh track the single-device
    trajectory at the same rtol as the star — both mesh layouts."""
    import warnings
    from jax.sharding import Mesh
    from repro.launch import mesh as mesh_lib
    views, labels = fixture_data()

    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        mesh_d = mesh_lib.make_inl_host_mesh(CFG.num_clients)  # data axis
    want, _ = _inl_trajectory(CFG, CHAIN)
    got = _sharded_trajectory(CFG, CHAIN, mesh_d, views, labels)
    np.testing.assert_allclose(got, want, rtol=1e-4)

    # client-sharded: J=4 divides the 2-device client axis; heterogeneous
    # first hops exercise the SPMD group masks
    cfg4 = dataclasses.replace(CFG, num_clients=4,
                               noise_stds=(0.4, 1.0, 2.0, 3.0))
    from repro.data import multiview
    imgs, labs4 = multiview.make_base_dataset(
        128, image_shape=CFG.image_shape, seed=0)
    views4 = jnp.asarray(multiview.make_views(imgs, cfg4.noise_stds))
    mesh_c = Mesh(np.array(jax.devices()[:2]).reshape(2, 1),
                  ("client", "data"))
    for topo in (T.chain(4), T.chain(4, link_bits=(2, 4, 8, 8))):
        scheme = schemes.get("inl")
        state = scheme.init(cfg4, jax.random.PRNGKey(0))
        round_fn = scheme.make_round(cfg4, topology=topo)
        v, lab = views4[None, :, :BATCH], jnp.asarray(labs4)[None, :BATCH]
        want = []
        for i in range(ROUNDS):
            state, m = round_fn(state, v, lab, jax.random.PRNGKey(i))
            want.append(float(m["loss"]))
        got = _sharded_trajectory(cfg4, topo, mesh_c, views4,
                                  jnp.asarray(labs4))
        np.testing.assert_allclose(got, want, rtol=1e-4,
                                   err_msg=f"{topo.describe()}")


# ---------------------------------------------------------------------------
# Degenerate constructors: one-view graphs and zero-size rejections
# ---------------------------------------------------------------------------

def _cfg1():
    return dataclasses.replace(CFG, num_clients=1,
                               noise_stds=(CFG.noise_stds[0],))


def test_single_view_constructors_are_valid_graphs():
    """star(1), chain(1) and tree(1,1) all collapse to the same one-edge
    graph; the closed-form per-edge ledger still sums to the round total
    and to INL's §III-C charge at J=1."""
    cfg1 = _cfg1()
    want = bandwidth.inl_epoch_bits(cfg1.d_bottleneck, BATCH, 1,
                                    cfg1.link_bits)
    for topo in (T.star(1), T.chain(1), T.tree(1, 1)):
        assert topo.num_views() == 1
        assert len(topo.topo_edges()) == 1
        edges = T.round_edge_bits(topo, cfg1, BATCH)
        assert sum(edges.values()) == T.round_bits(topo, cfg1, BATCH)
        assert sum(edges.values()) == want
    # chain(1) has no relay to speak of — it IS the default star
    assert T.chain(1).is_default_star()
    assert [e.key for e in T.chain(1).edges] == \
        [e.key for e in T.star(1).edges]


@pytest.mark.parametrize("k", (2, 3))
def test_tree_branching_one_is_a_chain(k):
    """tree(1,k) is a k-deep single-branch line: every hop carries the
    accumulated payload, so edge charges grow linearly toward the fuse
    and the ledger still sums exactly."""
    topo = T.tree(1, k)
    cfgk = dataclasses.replace(
        CFG, num_clients=k,
        noise_stds=tuple(CFG.noise_stds[j % len(CFG.noise_stds)]
                         for j in range(k)))
    assert topo.num_views() == k
    edges = T.round_edge_bits(topo, cfgk, BATCH)
    assert len(edges) == k
    base = 2 * BATCH * cfgk.d_bottleneck * cfgk.link_bits
    assert sorted(edges.values()) == [base * i for i in range(1, k + 1)]
    assert sum(edges.values()) == T.round_bits(topo, cfgk, BATCH)


def test_single_view_inl_round_and_ledger_agree():
    cfg1 = _cfg1()
    scheme = schemes.get("inl")
    state = scheme.init(cfg1, jax.random.PRNGKey(0))
    for topo in (T.star(1), T.tree(1, 1)):
        assert scheme.bits_per_round(cfg1, state, BATCH, topology=topo) \
            == T.round_bits(topo, cfg1, BATCH)


@pytest.mark.parametrize("make", [lambda: T.star(0), lambda: T.chain(0),
                                  lambda: T.tree(0, 1),
                                  lambda: T.tree(2, 0)],
                         ids=["star(0)", "chain(0)", "tree(0,1)",
                              "tree(2,0)"])
def test_zero_size_constructors_reject(make):
    with pytest.raises(ValueError):
        make()
