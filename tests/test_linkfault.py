"""Unreliable-link suite (core/linkfault.py).

The load-bearing property: attaching a PERFECT LinkModel() to every edge
routes execution through the fault-aware paths, and those paths are
bit-identical to the legacy fault-free code — all-ones delivery masks
multiply by exactly 1.0, the masked FedAvg average is exactly jnp.mean,
SL's jnp.where(True, new, old) is new.  The goldens therefore never need
to know faults exist.

The CI forced-erasure leg re-runs this file with REPRO_FORCE_ERASURE=0.3;
the lossy-training tests read `linkfault.forced_erasure(0.3)` so the env
var genuinely parameterises them (the bitwise-identity tests use explicit
perfect links and are immune by construction).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _schemes_common import BATCH, CFG, ROUNDS, fixture_data, round_inputs, \
    trajectory

from repro.core import bandwidth, linkfault, schemes
from repro.core import topology as T
from repro.core.schemes import base as schemes_base
from repro.data import multiview

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=2")

RTOL = 1e-4
PERFECT = linkfault.LinkModel()
LOSSY = linkfault.LinkModel(erasure=linkfault.forced_erasure(0.3))


def _views_for(cfg):
    views, labels = fixture_data()
    if cfg.num_clients <= views.shape[0]:
        return views[:cfg.num_clients], labels
    imgs, _ = multiview.make_base_dataset(128, image_shape=cfg.image_shape,
                                          seed=0)
    return jnp.asarray(multiview.make_views(imgs, cfg.noise_stds)), labels


def _run(name, cfg, topo, rounds=3):
    """`rounds` deterministic rounds; returns (losses, final state)."""
    views, labels = _views_for(cfg)
    scheme = schemes.get(name)
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    round_fn = scheme.make_round(cfg, topology=topo)
    v, lab = round_inputs(scheme, cfg, views, labels)
    losses = []
    for i in range(rounds):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    return losses, state


def _assert_states_equal(got, want, name):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(w),
            err_msg=f"{name}: perfect links perturbed the state")


# ---------------------------------------------------------------------------
# LinkModel / with_links construction
# ---------------------------------------------------------------------------

def test_linkmodel_validation():
    with pytest.raises(ValueError, match="erasure"):
        linkfault.LinkModel(erasure=1.0)
    with pytest.raises(ValueError, match="erasure"):
        linkfault.LinkModel(erasure=-0.1)
    with pytest.raises(ValueError, match="latency"):
        linkfault.LinkModel(latency_ms=-1.0)
    with pytest.raises(ValueError, match="bandwidth"):
        linkfault.LinkModel(bandwidth_bps=0.0)


def test_with_links_attaches_and_names_unknown_edges():
    star = T.star(3)
    lossy = linkfault.with_links(star, LOSSY)
    assert all(e.link == LOSSY for e in lossy.edges)
    assert linkfault.has_link_models(lossy)
    assert not linkfault.has_link_models(star)       # original untouched
    with pytest.raises(ValueError, match=r"\['nope->fuse'\]"):
        linkfault.with_links(star, {"nope->fuse": LOSSY})
    # dict form touches only the named edge
    one = linkfault.with_links(star, {"m0->fuse": LOSSY})
    assert one.edges[0].link == LOSSY
    assert one.edges[1].link is None


def test_activation_rule():
    star = T.star(CFG.num_clients)
    assert not linkfault.active(star, CFG, train=True)
    assert linkfault.active(linkfault.with_links(star, PERFECT), CFG,
                            train=True)
    drop = dataclasses.replace(CFG, edge_dropout=0.2)
    assert linkfault.active(star, drop, train=True)
    assert not linkfault.active(star, drop, train=False)   # inference clean


# ---------------------------------------------------------------------------
# Bitwise identity: a modelled-but-perfect network cannot move a trajectory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("inl", "fl", "sl"))
def test_perfect_star_bitwise_identity(name):
    """Fault path with all-ones masks == the legacy path, bit for bit —
    against the SAME cached trajectories the golden regression pins."""
    want = trajectory(name)
    perfect = linkfault.with_links(T.star(CFG.num_clients), PERFECT)
    losses, state = _run(name, CFG, perfect, rounds=ROUNDS)
    np.testing.assert_array_equal(np.asarray(losses),
                                  np.asarray(want["losses"]),
                                  err_msg=f"{name}: losses moved")
    _assert_states_equal(state, want["state"], name)


@pytest.mark.parametrize("make_topo", [
    lambda: T.chain(CFG.num_clients),
    lambda: T.tree(2, 2),
], ids=["chain", "tree(2,2)"])
def test_perfect_graph_bitwise_identity(make_topo):
    """Same identity on INL's multi-hop graphs (relay-hop path)."""
    topo = make_topo()
    cfg = CFG if topo.num_views() == CFG.num_clients else \
        dataclasses.replace(CFG, num_clients=topo.num_views(),
                            noise_stds=CFG.noise_stds + (1.5,))
    want_losses, want_state = _run("inl", cfg, topo)
    losses, state = _run("inl", cfg, linkfault.with_links(topo, PERFECT))
    np.testing.assert_array_equal(np.asarray(losses),
                                  np.asarray(want_losses))
    _assert_states_equal(state, want_state, "inl/" + topo.edges[0].key)


def test_lossy_links_do_change_the_trajectory():
    star = T.star(CFG.num_clients)
    want_losses, _ = _run("inl", CFG, star)
    losses, _ = _run("inl", CFG, linkfault.with_links(star, LOSSY))
    assert losses != want_losses, \
        "0.3-erasure links left the trajectory untouched"


# ---------------------------------------------------------------------------
# partial_fuse
# ---------------------------------------------------------------------------

def test_partial_fuse_all_ones_is_exact_identity():
    u = jax.random.normal(jax.random.PRNGKey(0), (5, 4, 8))
    np.testing.assert_array_equal(
        np.asarray(linkfault.partial_fuse(u, jnp.ones((5,), bool))),
        np.asarray(u))


def test_partial_fuse_renormalises_survivors():
    J = 4
    u = jnp.ones((J, 2, 3))
    mask = jnp.asarray([True, True, False, False])
    out = np.asarray(linkfault.partial_fuse(u, mask))
    np.testing.assert_allclose(out[:2], 2.0, rtol=1e-6)  # J/n = 4/2
    np.testing.assert_array_equal(out[2:], 0.0)
    # all dropped: the honest zero vector, no NaN from the 0/0 guard
    zero = np.asarray(linkfault.partial_fuse(u, jnp.zeros((J,), bool)))
    np.testing.assert_array_equal(zero, 0.0)


def test_partial_fuse_per_sample_mask():
    J, B, d = 3, 4, 2
    u = jnp.ones((J, B, d))
    mask = jnp.zeros((J, B), bool).at[:, 0].set(True).at[0, :].set(True)
    out = np.asarray(linkfault.partial_fuse(u, mask))
    np.testing.assert_allclose(out[:, 0], 1.0, rtol=1e-6)  # 3 of 3: scale 1
    np.testing.assert_allclose(out[0, 1:], 3.0, rtol=1e-6)  # 1 of 3 arrived
    np.testing.assert_array_equal(out[1:, 1:], 0.0)


# ---------------------------------------------------------------------------
# Deterministic draws, deadlines, stragglers
# ---------------------------------------------------------------------------

def test_fault_draws_deterministic_and_key_disjoint():
    topo = linkfault.with_links(T.star(4), LOSSY)
    rng = jax.random.PRNGKey(7)
    a = linkfault.round_delivery_mask(rng, topo, CFG, BATCH, train=True)
    b = linkfault.round_delivery_mask(rng, topo, CFG, BATCH, train=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # a fresh round key draws fresh faults
    masks = [np.asarray(linkfault.round_delivery_mask(
        jax.random.PRNGKey(k), topo, CFG, BATCH, train=True))
        for k in range(32)]
    assert any(not np.array_equal(masks[0], m) for m in masks[1:])


def test_deadline_cuts_stragglers():
    cfg = CFG
    slow = linkfault.with_links(
        T.star(3), linkfault.LinkModel(latency_ms=100.0))
    # deterministic latency 100ms: a 50ms deadline kills every view, 200ms
    # passes every view
    key = jax.random.PRNGKey(0)
    dead = linkfault.sample_delivery_mask(key, slow, cfg, 8, deadline=50.0)
    assert not bool(np.asarray(dead).any())
    ok = linkfault.sample_delivery_mask(key, slow, cfg, 8, deadline=200.0)
    assert bool(np.asarray(ok).all())
    # a bandwidth cap converts payload bits into transmission time: 1 bps
    # cannot ship a latent inside any sane deadline
    capped = linkfault.with_links(
        T.star(3), linkfault.LinkModel(bandwidth_bps=1.0))
    late = linkfault.sample_delivery_mask(key, capped, cfg, 8,
                                          deadline=1000.0)
    assert not bool(np.asarray(late).any())


def test_chain_routes_compound_erasure():
    """A view's delivery needs EVERY edge on its route: the chain head
    (longest route) must fail at least as often as the last hop."""
    topo = linkfault.with_links(T.chain(4),
                                linkfault.LinkModel(erasure=0.3))
    rates = np.mean([np.asarray(linkfault.round_delivery_mask(
        jax.random.PRNGKey(k), topo, CFG, BATCH, train=False))
        for k in range(400)], axis=0)
    assert rates[0] < rates[-1], \
        f"head view survived {rates[0]:.2f} >= tail {rates[-1]:.2f}"
    # the tail's single hop should sit near 1 - 0.3
    assert abs(rates[-1] - 0.7) < 0.1


# ---------------------------------------------------------------------------
# FL: masked FedAvg
# ---------------------------------------------------------------------------

def _fl_round_with_mask(monkeypatch, mask):
    cfg = dataclasses.replace(CFG, num_clients=2, noise_stds=(0.4, 2.0))
    lossy = linkfault.with_links(T.star(2), LOSSY)
    monkeypatch.setattr(
        linkfault, "client_delivery_mask",
        lambda rng, topo, c, train: jnp.asarray(mask))
    _, state = _run("fl", cfg, lossy, rounds=1)
    return jax.tree.leaves(state["params"])


def test_fl_masked_average_is_linear_in_the_mask(monkeypatch):
    """With J=2: avg(mask=[1,0]) + avg(mask=[0,1]) == 2 * avg(mask=[1,1])
    leaf by leaf — the masked average really averages the survivors."""
    p0 = _fl_round_with_mask(monkeypatch, [True, False])
    p1 = _fl_round_with_mask(monkeypatch, [False, True])
    both = _fl_round_with_mask(monkeypatch, [True, True])
    assert any(not np.allclose(a, b) for a, b in zip(p0, p1)), \
        "the two clients trained identical params — test is vacuous"
    for a, b, m in zip(p0, p1, both):
        np.testing.assert_allclose(np.asarray(a) + np.asarray(b),
                                   2.0 * np.asarray(m), rtol=1e-5,
                                   atol=1e-6)


def test_fl_all_dropped_keeps_previous_model(monkeypatch):
    cfg = dataclasses.replace(CFG, num_clients=2, noise_stds=(0.4, 2.0))
    lossy = linkfault.with_links(T.star(2), LOSSY)
    monkeypatch.setattr(
        linkfault, "client_delivery_mask",
        lambda rng, topo, c, train: jnp.zeros((2,), bool))
    views, labels = _views_for(cfg)
    scheme = schemes.get("fl")
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    before = jax.tree.map(np.asarray, state["params"])
    round_fn = scheme.make_round(cfg, topology=lossy)
    v, lab = round_inputs(scheme, cfg, views, labels)
    state, _ = round_fn(state, v, lab, jax.random.PRNGKey(0))
    for g, w in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(before)):
        np.testing.assert_array_equal(np.asarray(g), w)


# ---------------------------------------------------------------------------
# SL: bounded retry, round skip
# ---------------------------------------------------------------------------

def test_sl_round_skip_keeps_state_bitwise():
    cfg = CFG
    # erasure 0.999: find a round key whose 3 attempts all fail (virtually
    # all of them) and one that succeeds, deterministically
    topo = linkfault.with_links(T.star(cfg.num_clients),
                                linkfault.LinkModel(erasure=0.999))
    attempts = schemes.get("sl").max_link_retries + 1
    assert attempts == 3
    keys = {bool(linkfault.round_success(jax.random.PRNGKey(k), topo, cfg,
                                         attempts)): k for k in range(64)}
    assert False in keys, "no failing key in 64 draws at erasure 0.999?!"
    views, labels = _views_for(cfg)
    scheme = schemes.get("sl")
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    before = jax.tree.map(np.asarray, state)
    round_fn = scheme.make_round(cfg, topology=topo)
    v, lab = round_inputs(scheme, cfg, views, labels)
    after, _ = round_fn(state, v, lab, jax.random.PRNGKey(keys[False]))
    for g, w in zip(jax.tree.leaves(after), jax.tree.leaves(before)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
    if True in keys:        # a surviving round does train
        trained, _ = round_fn(state, v, lab,
                              jax.random.PRNGKey(keys[True]))
        assert any(not np.array_equal(np.asarray(g), np.asarray(w))
                   for g, w in zip(jax.tree.leaves(trained),
                                   jax.tree.leaves(before)))


def test_sl_retry_accounting():
    cfg = CFG
    charges = {None: (1000.0, 125.0)}
    clean = linkfault.with_links(T.star(cfg.num_clients), PERFECT)
    off, dlv = linkfault.round_fault_charges(
        jax.random.PRNGKey(0), "sl", clean, cfg, BATCH, charges)
    assert off == charges and dlv == charges       # one attempt, delivered
    lossy = linkfault.with_links(T.star(cfg.num_clients),
                                 linkfault.LinkModel(erasure=0.9))
    attempts = schemes.get("sl").max_link_retries + 1
    for k in range(256):
        oks = np.asarray(linkfault.attempt_successes(
            jax.random.PRNGKey(k), lossy, cfg, attempts))
        if not oks[0] and oks[1]:                  # fail, retry, succeed
            off, dlv = linkfault.round_fault_charges(
                jax.random.PRNGKey(k), "sl", lossy, cfg, BATCH, charges)
            assert off[None][0] == 2000.0          # two attempts offered
            assert dlv[None][0] == 1000.0          # one exchange delivered
            return
    pytest.fail("no fail-then-succeed key found at erasure 0.9")


# ---------------------------------------------------------------------------
# Delivered-vs-offered metering
# ---------------------------------------------------------------------------

def test_meter_delivery_ratio():
    m = bandwidth.BandwidthMeter()
    assert m.delivery_ratio == 1.0                 # idle
    m.add_edge("m0->fuse", bits=100.0, nbytes=10.0)
    m.add_delivered(bits=100.0, nbytes=10.0, edge="m0->fuse")
    assert m.delivery_ratio == 1.0                 # clean round
    m.add_edge("m1->fuse", bits=100.0, nbytes=10.0)
    m.add_delivered(bits=40.0, edge="m1->fuse")
    assert m.delivery_ratio == pytest.approx(0.7)
    assert m.edge_delivered_bits["m1->fuse"] == 40.0


def test_inl_fault_charges_track_the_mask():
    topo = linkfault.with_links(T.star(3), LOSSY)
    cfg = dataclasses.replace(CFG, num_clients=3,
                              noise_stds=CFG.noise_stds[:3])
    charges = {e.key: (90.0, 9.0) for e in topo.edges}
    rng = jax.random.PRNGKey(5)
    off, dlv = linkfault.round_fault_charges(rng, "inl", topo, cfg, BATCH,
                                             charges)
    assert off == charges
    mask = np.asarray(linkfault.round_delivery_mask(rng, topo, cfg, BATCH,
                                                    train=True))
    for j, e in enumerate(topo.edges):
        want = (90.0, 9.0) if mask[j] else (0.0, 0.0)
        assert dlv[e.key] == want


# ---------------------------------------------------------------------------
# Inference under faults
# ---------------------------------------------------------------------------

def test_predict_under_faults_clean_equals_predict():
    views, labels = fixture_data()
    scheme = schemes.get("inl")
    state = trajectory("inl")["state"]
    clean = linkfault.with_links(T.star(CFG.num_clients), PERFECT)
    a = schemes_base.evaluate_accuracy(scheme, state, views[:, :BATCH],
                                       labels[:BATCH], cfg=CFG)
    b = schemes_base.evaluate_accuracy_under_faults(
        scheme, state, views[:, :BATCH], labels[:BATCH],
        jax.random.PRNGKey(0), topology=clean, cfg=CFG)
    assert a == b


def test_degraded_requests_fall_back_to_uniform():
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(0), (4, 10)))
    ok = jnp.asarray([True, False, True, False])
    out = np.asarray(linkfault.degrade_probs(probs, ok))
    np.testing.assert_array_equal(out[0], np.asarray(probs)[0])
    np.testing.assert_allclose(out[1], 0.1, rtol=1e-6)
    np.testing.assert_allclose(out.sum(-1), 1.0, rtol=1e-6)


# ---------------------------------------------------------------------------
# Training under loss: end-to-end smoke + sharded parity
# ---------------------------------------------------------------------------

def test_inl_trains_through_lossy_links():
    """Six rounds over 0.3-erasure links + the dropout curriculum still
    learn (the e2e smoke the forced-erasure CI leg re-runs at its rate)."""
    cfg = dataclasses.replace(CFG, edge_dropout=0.2)
    lossy = linkfault.with_links(T.star(cfg.num_clients), LOSSY)
    losses, _ = _run("inl", cfg, lossy, rounds=ROUNDS)
    assert losses[-1] < losses[0], \
        f"loss did not improve under faults: {losses}"


CFG_J2 = dataclasses.replace(CFG, num_clients=2, noise_stds=(0.4, 2.0))


@multi_device
@pytest.mark.parametrize("name", ("inl", "fl"))
def test_sharded_parity_under_forced_erasure(name):
    """Fault draws are pure functions of the round rng, so the 2-device
    shard_map round sees the SAME faults as single-device — trajectories
    match at the suite's standard rtol despite the lossy network."""
    from repro.launch import mesh as mesh_lib
    cfg = CFG_J2 if name == "inl" else \
        dataclasses.replace(CFG_J2, edge_dropout=0.0)
    lossy = linkfault.with_links(T.star(2), LOSSY)
    views, labels = _views_for(cfg)
    scheme = schemes.get(name)
    v, lab = round_inputs(scheme, cfg, views, labels)

    def run(round_fn, state):
        losses = []
        for i in range(ROUNDS):
            state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
            losses.append(float(metrics["loss"]))
        state = jax.device_get(state)
        probs = scheme.predict(state, views[:, :BATCH])
        acc = float((jnp.argmax(probs, -1) == labels[:BATCH]).mean())
        return np.asarray(losses), acc

    want_losses, want_acc = run(scheme.make_round(cfg, topology=lossy),
                                scheme.init(cfg, jax.random.PRNGKey(0)))
    mesh = mesh_lib.make_inl_host_mesh(2)
    assert mesh.shape["client"] == 2
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    state = jax.device_put(state, scheme.state_shardings(cfg, state, mesh))
    got_losses, got_acc = run(
        scheme.make_sharded_round(cfg, mesh, topology=lossy), state)
    np.testing.assert_allclose(
        got_losses, want_losses, rtol=RTOL,
        err_msg=f"{name}: sharded faulty trajectory drifted")
    np.testing.assert_allclose(got_acc, want_acc, rtol=RTOL, atol=1e-6)
