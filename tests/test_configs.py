"""The assigned architectures must match the assignment sheet exactly."""
import pytest

from repro.configs import get_config, get_smoke_config, list_archs

ASSIGNED = {
    # name: (L, d_model, H, kv, d_ff, vocab)
    "xlstm-125m": (12, 768, 4, 4, 0, 50_304),
    "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151_936),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32_000),
    "llama3.2-1b": (16, 2048, 32, 8, 8192, 128_256),
    "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
    "internvl2-2b": (24, 2048, 16, 8, 8192, 92_553),
    "starcoder2-3b": (30, 3072, 24, 2, 12_288, 49_152),
    "deepseek-v2-236b": (60, 5120, 128, 128, 12_288, 102_400),
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13_440, 92_416),
    "zamba2-2.7b": (54, 2560, 32, 32, 10_240, 32_000),
}


def test_all_archs_registered():
    assert sorted(list_archs()) == sorted(ASSIGNED)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_assigned_numbers(name):
    L, d, H, kv, dff, vocab = ASSIGNED[name]
    cfg = get_config(name)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == dff or (name == "xlstm-125m" and cfg.d_ff == 0) \
        or (name == "deepseek-v2-236b")
    assert cfg.vocab_size == vocab


def test_moe_specs():
    arctic = get_config("arctic-480b")
    assert arctic.moe.num_experts == 128
    assert arctic.moe.experts_per_token == 2
    assert arctic.moe.dense_residual
    ds = get_config("deepseek-v2-236b")
    assert ds.moe.num_experts == 160
    assert ds.moe.experts_per_token == 6
    assert ds.moe.num_shared_experts == 2
    assert ds.moe.d_ff_expert == 1536
    assert ds.use_mla and ds.mla.kv_lora_rank == 512


def test_ssm_specs():
    z = get_config("zamba2-2.7b")
    assert z.ssm.state_dim == 64
    assert "mamba+shared_attn" in z.block_pattern
    x = get_config("xlstm-125m")
    assert {"mlstm", "slstm"} <= set(x.block_pattern)


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_smoke_configs_reduced(name):
    cfg = get_smoke_config(name)
    assert cfg.num_layers <= 4
    assert cfg.d_model <= 512
    assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_param_counts_sane(name):
    """Analytic N within the ballpark implied by the arch's marketing size."""
    cfg = get_config(name)
    n = cfg.param_count()
    expect = {"xlstm-125m": 125e6, "qwen1.5-4b": 4e9, "arctic-480b": 480e9,
              "llama3.2-1b": 1.2e9, "musicgen-medium": 1.5e9,
              "internvl2-2b": 2e9, "starcoder2-3b": 3e9,
              "deepseek-v2-236b": 236e9, "codeqwen1.5-7b": 7e9,
              "zamba2-2.7b": 2.7e9}[name]
    assert 0.4 * expect < n < 2.2 * expect, f"{name}: N={n:.3e}"


def test_inl_eq5_widths():
    """Eq. (5): sum of bottleneck widths == decoder input width (== d_model
    by our convention)."""
    for name in sorted(ASSIGNED):
        cfg = get_config(name)
        assert cfg.inl.num_nodes * cfg.inl.d_bottleneck == cfg.d_model, name
