"""The packed wire format (core/wirefmt.py + the pack/unpack kernels) and
the mixed-precision compute policy.

Contracts pinned here:

  * pack -> unpack is the IDENTITY against ref.quantize_value for every
    packable width (bits in {1,2,3,4,8,16}), including odd-d tail padding —
    property-tested via tests/_hyp.py;
  * the wire wrappers (`ship`, `cut_and_ship`) leave values AND gradients
    bit-identical to the dense path for wire="packed" (packing is a
    re-encoding, not a second quantizer), while "packed_duplex" compresses
    only the backward link;
  * scheme trajectories: packed == dense exactly, duplex within a loose
    bound (its backward link is genuinely lossy);
  * measured bytes come from the real buffers (the eval_shape-derived
    accounting equals the `.nbytes` of what the ops produce);
  * cfg.compute_dtype="bf16": the hot path runs bf16 (latents bf16), while
    grads, optimizer/master params, BatchNorm stats and the rate stay fp32.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import schemes, wirefmt
from repro.kernels import inl_bottleneck as bn
from repro.kernels import ref

# Tiny-but-real fixture (J=2 so the wire crosses a genuine client axis)
CFG = PaperExperimentConfig(conv_channels=(4,), d_bottleneck=8,
                            dense_units=(32,), image_shape=(16, 16, 3),
                            num_clients=2, noise_stds=(0.4, 2.0),
                            dataset_size=64, link_bits=8)
BATCH = 16
ROUNDS = 4


# ---------------------------------------------------------------------------
# pack/unpack identity (satellite: property tests incl. odd-d tails)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2 ** 16), d=st.sampled_from([1, 7, 8, 13, 64]),
       bits=st.sampled_from([1, 2, 3, 4, 8]))
def test_pack_unpack_identity_property(seed, d, bits):
    """unpack(pack(quantize(x))) == quantize(x) bit-for-bit, any width/d."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (33, d)) * 3.0
    u = ref.quantize_value(x, bits)
    packed = ref.pack_values_ref(u, bits)
    assert packed.dtype == jnp.uint32
    assert packed.shape == (33, ref.packed_width(d, bits))
    np.testing.assert_array_equal(
        np.asarray(ref.unpack_dequant_ref(packed, d, bits)), np.asarray(u))


def test_packed_width_counts_lane_capacity():
    assert ref.vals_per_word(2) == 16 and ref.vals_per_word(8) == 4
    assert ref.vals_per_word(3) == 10                  # 2 padding bits/lane
    assert ref.packed_width(64, 2) == 4                # 16 bytes == 64*2/8
    assert ref.packed_width(13, 4) == 2                # tail padded
    with pytest.raises(ValueError):
        ref.vals_per_word(32)


def test_dequantize_index_matches_quantize_value():
    x = jax.random.normal(jax.random.PRNGKey(0), (50, 9)) * 5.0   # clips too
    for bits in (1, 3, 8, 16):
        np.testing.assert_array_equal(
            np.asarray(ref.dequantize_index(ref.quantize_index(x, bits),
                                            bits)),
            np.asarray(ref.quantize_value(x, bits)))


@pytest.mark.kernel_interpret
@pytest.mark.parametrize("bits", [2, 3, 8])
def test_pallas_pack_kernels_match_ref(bits):
    """Interpret-mode Pallas pack / unpack / pack-emitting-forward kernels
    == the jnp oracles bitwise (odd rows exercise the padding)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    mu = jax.random.normal(ks[0], (97, 16))
    lv = jax.random.normal(ks[1], (97, 16)) * 0.3
    eps = jax.random.normal(ks[2], (97, 16))
    u_r, pk_r, rate_r = bn.cutlayer_pack_forward(
        mu, lv, eps, link_bits=bits, rate_estimator="sample",
        impl="reference")
    u_p, pk_p, rate_p = bn.cutlayer_pack_forward(
        mu, lv, eps, link_bits=bits, rate_estimator="sample", impl="pallas",
        block_t=64)
    np.testing.assert_array_equal(np.asarray(u_r), np.asarray(u_p))
    np.testing.assert_array_equal(np.asarray(pk_r), np.asarray(pk_p))
    np.testing.assert_allclose(np.asarray(rate_r), np.asarray(rate_p),
                               rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(bn.pack_values(u_r, link_bits=bits, impl="pallas",
                                  block_t=64)),
        np.asarray(pk_r))
    np.testing.assert_array_equal(
        np.asarray(bn.unpack_dequant(pk_p, 16, link_bits=bits,
                                     impl="pallas", block_t=64)),
        np.asarray(u_r))


def test_pack_emitting_forward_matches_dense_kernel():
    """(u, rate) of the pack-emitting forward == the plain fused kernel
    bitwise — the packed lanes are a free extra output, not a new path."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    mu = jax.random.normal(ks[0], (130, 24))
    lv = jax.random.normal(ks[1], (130, 24)) * 0.4
    eps = jax.random.normal(ks[2], (130, 24))
    for mode in ("sample", "analytic", "none"):
        u1, pk, r1 = bn.cutlayer_pack_forward(mu, lv, eps, link_bits=4,
                                              rate_estimator=mode,
                                              impl="reference")
        u2, r2 = ops.cutlayer(mu, lv, eps, link_bits=4, rate_estimator=mode,
                              backend="reference")
        np.testing.assert_array_equal(np.asarray(u1), np.asarray(u2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        np.testing.assert_array_equal(
            np.asarray(bn.unpack_dequant(pk, 24, link_bits=4,
                                         impl="reference")),
            np.asarray(u1))


# ---------------------------------------------------------------------------
# wire wrappers: values and gradients
# ---------------------------------------------------------------------------

def _wire_loss(wire, cu, cr, cs):
    def f(mu, lv):
        u, rate, us = wirefmt.cut_and_ship(
            jax.random.PRNGKey(7), mu, lv, link_bits=4, wire=wire,
            backend="reference")
        return ((u * cu).sum() + (rate * cr).sum()
                + (us * cs).sum())
    return f


def test_packed_wire_is_bit_identical_to_dense():
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    mu = jax.random.normal(ks[0], (2, 40, 16))
    lv = jax.random.normal(ks[1], (2, 40, 16)) * 0.3
    cu, cs = (jax.random.normal(k, (2, 40, 16)) for k in ks[2:4])
    cr = jax.random.normal(ks[4], (2, 40))
    vd, gd = jax.value_and_grad(_wire_loss("dense", cu, cr, cs),
                                argnums=(0, 1))(mu, lv)
    vp, gp = jax.value_and_grad(_wire_loss("packed", cu, cr, cs),
                                argnums=(0, 1))(mu, lv)
    assert float(vd) == float(vp)
    for a, b in zip(gd, gp):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_duplex_wire_quantizes_only_the_backward_link():
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    mu = jax.random.normal(ks[0], (2, 40, 16))
    lv = jax.random.normal(ks[1], (2, 40, 16)) * 0.3
    cu, cs = (jax.random.normal(k, (2, 40, 16)) for k in ks[2:4])
    cr = jax.random.normal(ks[4], (2, 40))
    vd = _wire_loss("dense", cu, cr, cs)(mu, lv)
    vq = _wire_loss("packed_duplex", cu, cr, cs)(mu, lv)
    assert float(vd) == float(vq)                  # forward identical
    gd = jax.grad(_wire_loss("dense", cu, cr, cs), argnums=(0, 1))(mu, lv)
    gq = jax.grad(_wire_loss("packed_duplex", cu, cr, cs),
                  argnums=(0, 1))(mu, lv)
    diff = float(jnp.max(jnp.abs(gd[0] - gq[0])))
    assert 0.0 < diff < 0.5                        # lossy but bounded


def test_resolve_wire_rejects_unpackable_widths():
    with pytest.raises(ValueError):
        wirefmt.resolve_wire("packed", 32)
    with pytest.raises(ValueError):
        wirefmt.resolve_wire("zip", 8)
    assert wirefmt.resolve_wire("dense", 32) == ("dense", None)
    assert wirefmt.resolve_wire("packed_duplex", 4) == ("packed_duplex", 4)


def test_measured_bytes_survive_bf16_at_wide_codes():
    """Metering a packed wire at 9..16-bit codes under the bf16 policy must
    not trip pack_values' bf16 re-encode guard: the training path packs
    from the kernel's fp32 internals, and lane sizes are dtype-independent
    (regression: the ledger used to crash after training had succeeded)."""
    wb = wirefmt.round_wire_bytes(10, 64, link_bits=12, wire="packed",
                                  dtype=jnp.bfloat16)
    assert wb["fwd"] == 10 * ref.packed_width(64, 12) * 4
    assert wb["bwd"] == 10 * 64 * 2                    # dense bf16 backward


def test_measured_bytes_equal_real_buffer_nbytes():
    """The eval_shape-derived accounting == the .nbytes of the buffers the
    ops actually produce (the meter measures, it does not re-derive)."""
    u = ref.quantize_value(
        jax.random.normal(jax.random.PRNGKey(5), (10, 13)), 4)
    packed = bn.pack_values(u, link_bits=4, impl="reference")
    assert wirefmt.shipped_nbytes(10, 13, link_bits=4, wire="packed") == \
        packed.nbytes
    assert wirefmt.shipped_nbytes(10, 13, link_bits=4, wire="dense") == \
        np.asarray(u).nbytes
    wb = wirefmt.round_wire_bytes(10, 13, link_bits=4, wire="packed_duplex")
    assert wb["fwd"] == wb["bwd"] == packed.nbytes
    assert wb["total"] == 2 * packed.nbytes


# ---------------------------------------------------------------------------
# scheme trajectories under each wire format
# ---------------------------------------------------------------------------

import functools


@functools.lru_cache(maxsize=None)
def _fixture():
    from repro.data import multiview
    imgs, labels = multiview.make_base_dataset(
        64, image_shape=CFG.image_shape, seed=0)
    views = multiview.make_views(imgs, CFG.noise_stds)
    return jnp.asarray(views), jnp.asarray(labels)


@functools.lru_cache(maxsize=None)
def _trajectory(name, cfg, wire):
    views, labels = _fixture()
    scheme = schemes.get(name)
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    round_fn = scheme.make_round(cfg, wire=wire)
    R = scheme.batches_per_round(cfg)
    v = jnp.broadcast_to(views[None, :, :BATCH],
                         (R,) + views[:, :BATCH].shape)
    lab = jnp.broadcast_to(labels[None, :BATCH], (R, BATCH))
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    return np.asarray(losses), state


@pytest.mark.parametrize("name", ["inl", "sl"])
def test_packed_trajectory_is_exact(name):
    """wire="packed" == "dense" round for round, bit for bit: the collective
    payload changed representation, nothing else."""
    dense, _ = _trajectory(name, CFG, "dense")
    packed, _ = _trajectory(name, CFG, "packed")
    np.testing.assert_array_equal(packed, dense)


def test_duplex_trajectory_tracks_dense_loosely():
    """The duplex backward link is lossy at 8 bits — the trajectory must
    stay close (it carries real training signal) but need not match."""
    dense, _ = _trajectory("inl", CFG, "dense")
    duplex, _ = _trajectory("inl", CFG, "packed_duplex")
    np.testing.assert_allclose(duplex, dense, rtol=0.05)
    assert duplex[-1] < duplex[0]                  # still trains


def test_learned_prior_rides_the_packed_wire():
    """cfg.learned_prior routes through the prior kernel + standalone ship:
    packed must still match dense exactly."""
    cfg = dataclasses.replace(CFG, learned_prior=True)
    dense, st_d = _trajectory("inl", cfg, "dense")
    packed, st_p = _trajectory("inl", cfg, "packed")
    np.testing.assert_array_equal(packed, dense)
    for a, b in zip(jax.tree.leaves(st_d["params"].priors),
                    jax.tree.leaves(st_p["params"].priors)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=2")
def test_sharded_packed_collective_matches_single_device():
    """The 'client'-axis all_gather rides the packed buffer: the sharded
    packed round == the single-device dense round at rtol 1e-4 (same bound
    the dense sharded parity is held to)."""
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_inl_host_mesh(CFG.num_clients)
    assert mesh.shape["client"] == 2
    views, labels = _fixture()
    scheme = schemes.get("inl")
    want, _ = _trajectory("inl", CFG, "dense")
    state = scheme.init(CFG, jax.random.PRNGKey(0))
    state = jax.device_put(state, scheme.state_shardings(CFG, state, mesh))
    round_fn = scheme.make_sharded_round(CFG, mesh, wire="packed")
    v = views[None, :, :BATCH]
    lab = labels[None, :BATCH]
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    np.testing.assert_allclose(np.asarray(losses), want, rtol=1e-4)


# ---------------------------------------------------------------------------
# mixed-precision compute policy
# ---------------------------------------------------------------------------

BF16_CFG = dataclasses.replace(CFG, compute_dtype="bf16")


def test_bf16_policy_runs_hot_path_in_bf16_with_fp32_masters():
    """The policy contract: latents bf16 on the wire, rate fp32, gradients
    and updated params fp32 (mixed-precision master copies)."""
    from repro.core import inl
    views, labels = _fixture()
    params, state = inl.init(BF16_CFG, jax.random.PRNGKey(0))

    def probe(params):
        loss, (metrics, _) = inl.loss_fn(
            params, state, views[:, :BATCH], labels[:BATCH],
            jax.random.PRNGKey(1), BF16_CFG)
        return loss, metrics
    (loss, metrics), grads = jax.value_and_grad(probe, has_aux=True)(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert g.dtype == jnp.float32              # master-grad precision
    # the latent that crosses the wire is bf16 under the policy
    from repro.core import paper_model
    dt = jax.eval_shape(
        lambda p, v: inl.encode_and_rate(
            p, state, v, train=True, rng=jax.random.PRNGKey(2))[0],
        paper_model.cast_compute(params, jnp.bfloat16),
        views[:, :BATCH].astype(jnp.bfloat16)).dtype
    assert dt == jnp.bfloat16


@pytest.mark.parametrize("name", [
    "inl", "sl",
    pytest.param("fl", marks=pytest.mark.slow),   # FL round compile is heavy
])
def test_bf16_policy_trains_every_scheme(name):
    losses, state = _trajectory(name, BF16_CFG, "dense")
    assert np.all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses
    if name == "inl":
        # BatchNorm statistics stayed fp32 under the policy
        for leaf in jax.tree.leaves(state["state"]):
            assert leaf.dtype == jnp.float32


def test_bf16_policy_tracks_fp32_loosely():
    fp32, _ = _trajectory("inl", CFG, "dense")
    bf16, _ = _trajectory("inl", BF16_CFG, "dense")
    np.testing.assert_allclose(bf16, fp32, rtol=0.1)


def test_bf16_packed_wire_re_encodes_exactly():
    """bf16 latents at link_bits <= 8: the packed wire is still an exact
    re-encoding (the 8-bit grid is coarser than the bf16 mantissa)."""
    fp = ref.quantize_value(
        jax.random.normal(jax.random.PRNGKey(6), (40, 16)) * 2, 8)
    u = fp.astype(jnp.bfloat16)
    back = bn.unpack_dequant(bn.pack_values(u, link_bits=8,
                                            impl="reference"),
                             16, link_bits=8, dtype=jnp.bfloat16,
                             impl="reference")
    np.testing.assert_array_equal(np.asarray(back, np.float32),
                                  np.asarray(u, np.float32))
    with pytest.raises(ValueError):                # >8-bit codes rejected
        bn.pack_values(u, link_bits=16, impl="reference")


@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=2")
def test_bf16_packed_sharded_round_runs():
    """The CI bf16-policy leg: mixed precision + packed collectives over a
    real 2-device ('client', 'data') mesh in one round body."""
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_inl_host_mesh(BF16_CFG.num_clients)
    views, labels = _fixture()
    scheme = schemes.get("inl")
    state = scheme.init(BF16_CFG, jax.random.PRNGKey(0))
    state = jax.device_put(state,
                           scheme.state_shardings(BF16_CFG, state, mesh))
    round_fn = scheme.make_sharded_round(BF16_CFG, mesh, wire="packed")
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, views[None, :, :BATCH],
                                  labels[None, :BATCH], jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    assert np.all(np.isfinite(losses)) and losses[-1] < losses[0]
