"""Sharded-vs-single-device parity for the scheme execution layer.

Three ladders, each pinned to the single-device trajectories:

  1. the whole-epoch lax.scan (Scheme.make_epoch) must reproduce the
     per-round dispatch loop — runs at any device count (tier-1 everywhere);
  2. the shard_map rounds (core/sharded.py) under a forced 2-device host
     (CI leg with XLA_FLAGS=--xla_force_host_platform_device_count=2) must
     match the same trajectories at rtol 1e-4, on BOTH mesh layouts:
     (client=2, data=1) — node-parallel, exercising the all_gather fan-in
     and client psums — and (client=1, data=2) — batch-parallel, exercising
     collective BatchNorm stats and data pmeans;
  3. the registry runner's mesh path end-to-end (accuracy + bandwidth).

Single-device trajectories come from tests/_schemes_common.py, the same
fixtures the golden-metric regression pins to checked-in JSON — so sharded
execution is transitively pinned to the golden record.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _schemes_common import BATCH, CFG, ROUNDS, fixture_data, round_inputs, \
    trajectory

from repro.core import schemes
from repro.core.schemes import runner
from repro.data import multiview
from repro.launch import mesh as mesh_lib

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=2")

RTOL = 1e-4
SCHEMES = ("inl", "fl", "sl")


def _epoch_trajectory(name, cfg, mesh=None):
    """ROUNDS rounds through Scheme.make_epoch (one scan dispatch), same
    fixed inputs + per-round keys as _schemes_common.trajectory."""
    views, labels = fixture_data()
    scheme = schemes.get(name)
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    if mesh is not None:
        state = jax.device_put(state,
                               scheme.state_shardings(cfg, state, mesh))
    epoch_fn = scheme.make_epoch(cfg, mesh=mesh)
    v, lab = round_inputs(scheme, cfg, views, labels)
    vs = jnp.broadcast_to(v[None], (ROUNDS,) + v.shape)
    labs = jnp.broadcast_to(lab[None], (ROUNDS,) + lab.shape)
    rngs = jnp.stack([jax.random.PRNGKey(i) for i in range(ROUNDS)])
    state, metrics = epoch_fn(state, vs, labs, rngs)
    state = jax.device_get(state)
    probs = scheme.predict(state, views[:, :BATCH])
    acc = float((jnp.argmax(probs, -1) == labels[:BATCH]).mean())
    return {"losses": np.asarray(metrics["loss"]), "final_accuracy": acc}


@pytest.mark.parametrize("name", SCHEMES)
def test_epoch_scan_matches_per_round(name):
    """One scan dispatch == ROUNDS per-round dispatches (any device count)."""
    want = trajectory(name)
    got = _epoch_trajectory(name, CFG)
    np.testing.assert_allclose(got["losses"], want["losses"], rtol=RTOL,
                               err_msg=f"{name}: whole-epoch scan drifted "
                                       "from the per-round loop")
    np.testing.assert_allclose(got["final_accuracy"],
                               want["final_accuracy"], rtol=RTOL, atol=1e-6)


@multi_device
@pytest.mark.parametrize("name", SCHEMES)
def test_data_sharded_matches_golden_trajectory(name):
    """(client=1, data=2): batch sharded over 'data' — collective BN stats,
    pmean'd grads; J=5 does not divide 2 devices so the host-mesh helper
    falls back to replicated clients (with its warning)."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", UserWarning)
        mesh = mesh_lib.make_inl_host_mesh(CFG.num_clients)
    assert mesh.shape["client"] == 1 and mesh.shape["data"] >= 2
    want = trajectory(name)
    got = _epoch_trajectory(name, CFG, mesh=mesh)
    np.testing.assert_allclose(got["losses"], want["losses"], rtol=RTOL,
                               err_msg=f"{name}: data-sharded trajectory "
                                       "drifted from single-device")
    np.testing.assert_allclose(got["final_accuracy"],
                               want["final_accuracy"], rtol=RTOL, atol=1e-6)


# J=2 fits the client axis of a 2-device mesh exactly: node-parallel path.
import dataclasses

CFG_J2 = dataclasses.replace(CFG, num_clients=2, noise_stds=(0.4, 2.0))


def _single_device_trajectory(name, cfg):
    views, labels = fixture_data()
    views = views[:cfg.num_clients]
    scheme = schemes.get(name)
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    round_fn = scheme.make_round(cfg)
    v, lab = round_inputs(scheme, cfg, views, labels)
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    probs = scheme.predict(state, views[:, :BATCH])
    acc = float((jnp.argmax(probs, -1) == labels[:BATCH]).mean())
    return {"losses": np.asarray(losses), "final_accuracy": acc}


def _client_sharded_trajectory(name, cfg, mesh):
    views, labels = fixture_data()
    views = views[:cfg.num_clients]
    scheme = schemes.get(name)
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    state = jax.device_put(state, scheme.state_shardings(cfg, state, mesh))
    round_fn = scheme.make_sharded_round(cfg, mesh)
    v, lab = round_inputs(scheme, cfg, views, labels)
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    state = jax.device_get(state)
    probs = scheme.predict(state, views[:, :BATCH])
    acc = float((jnp.argmax(probs, -1) == labels[:BATCH]).mean())
    return {"losses": np.asarray(losses), "final_accuracy": acc}


@multi_device
@pytest.mark.parametrize("name,learned_prior", [
    ("inl", False), ("fl", False), ("inl", True)],
    ids=["inl", "fl", "inl+learned_prior"])
def test_client_sharded_matches_single_device(name, learned_prior):
    """(client=2, data=1): the J branches run node-parallel; INL's fusion
    fan-in is the all_gather collective, FL's aggregation the psum.  The
    per-node compute is untouched, so parity here is essentially exact.
    The learned-prior case puts the kernel's (J, d) prior grid — and its
    in-kernel prior gradients — on the client axis too."""
    mesh = mesh_lib.make_inl_host_mesh(CFG_J2.num_clients)
    assert mesh.shape["client"] == 2
    cfg = dataclasses.replace(CFG_J2, learned_prior=True) if learned_prior \
        else CFG_J2
    want = _single_device_trajectory(name, cfg)
    got = _client_sharded_trajectory(name, cfg, mesh)
    np.testing.assert_allclose(got["losses"], want["losses"], rtol=RTOL,
                               err_msg=f"{name}: client-sharded trajectory "
                                       "drifted from single-device")
    np.testing.assert_allclose(got["final_accuracy"],
                               want["final_accuracy"], rtol=RTOL, atol=1e-6)


@multi_device
def test_runner_mesh_curve_matches_per_round():
    """End-to-end: run_scheme(mesh=...) reproduces the seed-style per-round
    dispatch curve (accuracy AND §III-C bandwidth accounting)."""
    views, labels = fixture_data()
    views, labels = np.asarray(views[:2, :64]), np.asarray(labels[:64])
    cfg = CFG_J2
    mesh = mesh_lib.make_inl_host_mesh(cfg.num_clients)
    for name in ("inl", "sl"):
        base_curve = runner.run_scheme(name, views, labels, cfg, epochs=2,
                                       batch_size=16, eval_n=64,
                                       dispatch="per_round")
        mesh_curve = runner.run_scheme(name, views, labels, cfg, epochs=2,
                                       batch_size=16, eval_n=64,
                                       dispatch="scan", mesh=mesh)
        for a, b in zip(base_curve, mesh_curve):
            np.testing.assert_allclose(b.accuracy, a.accuracy, rtol=RTOL)
            np.testing.assert_allclose(b.gbits, a.gbits, rtol=1e-6)


def test_host_mesh_divisibility_fallback():
    """J that does not divide the device count falls back to replicated
    clients with a warning instead of erroring (satellite fix)."""
    n = jax.device_count()
    with pytest.warns(UserWarning, match="replicated client axis"):
        mesh = mesh_lib.make_inl_host_mesh(n + 1)
    assert mesh.shape["client"] == 1
    assert mesh.shape["data"] == n
    # the divisible case keeps the client axis (no warning)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mesh = mesh_lib.make_inl_host_mesh(n)
    assert mesh.shape["client"] == n


def test_batch_indices_drop_remainder_and_seeding():
    """The unified generator: full batches only, deterministic in seed,
    identical stream for the multiview/image wrappers (the dedup)."""
    idx = list(multiview.batch_indices(50, 16, seed=3))
    assert [len(i) for i in idx] == [16, 16, 16]          # 50 % 16 dropped
    assert sorted(np.concatenate(idx).tolist()) == sorted(
        np.concatenate(list(multiview.batch_indices(50, 16, seed=3)))
        .tolist())
    views = np.arange(2 * 10 * 4).reshape(2, 10, 4).astype(np.float32)
    labels = np.arange(10).astype(np.int32)
    mv = list(multiview.multiview_batches(views, labels, 4, seed=7))
    im = list(multiview.image_batches(views[0], labels, 4, seed=7))
    assert len(mv) == len(im) == 2
    for (v, l), (x, l2) in zip(mv, im):
        assert v.shape == (2, 4, 4) and x.shape == (4, 4)
        np.testing.assert_array_equal(l, l2)              # same index stream
        np.testing.assert_array_equal(v[0], x)


def test_prefetch_preserves_order_and_values():
    from repro.data import prefetch
    items = [{"a": np.full((3,), i), "b": np.int32(i)} for i in range(5)]
    out = list(prefetch.prefetch_to_device(iter(items), size=2))
    assert len(out) == 5
    for i, it in enumerate(out):
        np.testing.assert_array_equal(np.asarray(it["a"]), items[i]["a"])
        assert int(it["b"]) == i


def test_prefetch_reraises_producer_exception():
    """A fault in the source iterator surfaces on the CONSUMER side — with
    the good items already buffered still delivered first and the consumer
    never blocking on the dead producer thread."""
    from repro.data import prefetch

    def flaky():
        yield np.zeros((2,))
        yield np.ones((2,))
        raise RuntimeError("disk fell over")

    it = prefetch.prefetch_to_device(flaky(), size=2)
    np.testing.assert_array_equal(np.asarray(next(it)), np.zeros((2,)))
    np.testing.assert_array_equal(np.asarray(next(it)), np.ones((2,)))
    with pytest.raises(RuntimeError, match="disk fell over"):
        next(it)


def test_prefetch_reraises_immediate_exception():
    # producer dies before yielding anything: first pull must raise, not hang
    from repro.data import prefetch

    def dead():
        raise ValueError("bad shard spec")
        yield  # pragma: no cover

    with pytest.raises(ValueError, match="bad shard spec"):
        next(prefetch.prefetch_to_device(dead()))


def test_prefetch_consumer_can_stop_early():
    # dropping the generator mid-stream releases the producer (no deadlock
    # on the bounded queue) and keeps already-buffered items correct
    from repro.data import prefetch
    items = [np.full((2,), i) for i in range(100)]
    it = prefetch.prefetch_to_device(iter(items), size=2)
    assert int(np.asarray(next(it))[0]) == 0
    it.close()
