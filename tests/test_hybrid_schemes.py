"""The hybrid schemes (core/schemes/splitfed.py, hybrid.py): the same
parity gauntlet every registry plugin passes — loss improves, predict is
a distribution, closed-form bits == edge ledger == metered bytes, perfect
links are bitwise invisible, checkpoints resume bit-identically — plus
the knobs the pure schemes don't have (cut_depth, hybrid_fl_clients).

The lossy tests read `linkfault.forced_erasure(0.3)` so the CI
forced-erasure leg (REPRO_FORCE_ERASURE=0.3) genuinely parameterises
them; the bitwise-identity tests use explicit perfect links and are
immune by construction.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _schemes_common import BATCH, CFG, ROUNDS, fixture_data, trajectory

from repro.core import bandwidth, linkfault, paper_model, schemes
from repro.core import topology as T
from repro.core.schemes import splitfed as splitfed_lib
from repro.core.schemes import hybrid as hybrid_lib

HYBRIDS = ("splitfed", "hybrid")
PERFECT = linkfault.LinkModel()
LOSSY = linkfault.LinkModel(erasure=linkfault.forced_erasure(0.3))


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registered():
    names = schemes.available()
    for name in HYBRIDS:
        assert name in names
    assert names[:3] == ("inl", "sl", "fl") or set(names[:3]) == \
        {"inl", "sl", "fl"}                      # paper schemes lead


def test_unknown_scheme_error_lists_registered():
    """The KeyError is a catalogue, not a shrug: it must name every
    registered scheme so the caller can fix the spelling in place."""
    with pytest.raises(KeyError) as ei:
        schemes.get("splitfedv2")
    msg = str(ei.value)
    for name in ("inl", "fl", "sl") + HYBRIDS:
        assert f"'{name}'" in msg, msg


# ---------------------------------------------------------------------------
# training contract (shared cached trajectories)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", HYBRIDS)
def test_loss_improves(name):
    traj = trajectory(name)
    assert all(np.isfinite(traj["losses"]))
    assert traj["losses"][-1] < traj["losses"][0]


@pytest.mark.parametrize("name", HYBRIDS)
def test_predict_is_distribution(name):
    views, labels = fixture_data()
    state = trajectory(name)["state"]
    probs = schemes.get(name).predict(state, views[:, :BATCH])
    assert probs.shape == (BATCH, CFG.num_classes)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, rtol=1e-5)
    assert (np.asarray(probs) >= 0).all()


# ---------------------------------------------------------------------------
# bandwidth: closed form == per-edge ledger == metered == measured bytes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", HYBRIDS)
def test_ledger_parity(name):
    views, labels = fixture_data()
    scheme = schemes.get(name)
    state = scheme.init(CFG, jax.random.PRNGKey(0))
    closed = scheme.bits_per_round(CFG, state, BATCH)
    ledger = scheme.edge_ledger(CFG, state, BATCH)
    assert abs(sum(b for b, _ in ledger.values()) - closed) < 1.0
    nbytes = scheme.wire_bytes_per_round(CFG, state, BATCH)
    assert abs(sum(n for _, n in ledger.values()) - nbytes) < 1.0
    # fp32 dense at q=32: the wire ships exactly what the formula charges
    assert abs(nbytes * 8 - closed) < 1.0

    meter = bandwidth.BandwidthMeter()
    curve = schemes.runner.run_scheme(
        name, views, labels, CFG, epochs=1, batch_size=BATCH,
        eval_n=64, meter=meter)
    rounds = schemes.runner.rounds_per_epoch(
        scheme, CFG, CFG.dataset_size, BATCH)
    assert abs(meter.total_bits - rounds * closed) < 1.0
    assert abs(meter.measured_bytes - rounds * nbytes) < 1.0
    assert curve[-1].gbits == pytest.approx(meter.total_bits / 1e9)


# ---------------------------------------------------------------------------
# cut_depth
# ---------------------------------------------------------------------------

def test_cut_depth_truncates_client_trunk():
    deep = dataclasses.replace(CFG, conv_channels=(4, 8))
    shallow = dataclasses.replace(deep, cut_depth=1)
    assert splitfed_lib.client_cfg(shallow).conv_channels == (4,)
    assert splitfed_lib.client_cfg(deep).conv_channels == (4, 8)
    # a shallower cut is NOT automatically cheaper: the truncated trunk
    # pools less, so the flatten feeding the dense cut head grows — the
    # knob genuinely moves the weight leg of the exchange, and the search
    # prices it rather than assuming a direction
    n_shallow = paper_model.encoder_param_count(
        splitfed_lib.client_cfg(shallow))
    n_deep = paper_model.encoder_param_count(splitfed_lib.client_cfg(deep))
    assert n_shallow != n_deep
    scheme = schemes.get("splitfed")
    s_shallow = scheme.init(shallow, jax.random.PRNGKey(0))
    s_deep = scheme.init(deep, jax.random.PRNGKey(0))
    b_shallow = scheme.bits_per_round(shallow, s_shallow, BATCH)
    b_deep = scheme.bits_per_round(deep, s_deep, BATCH)
    assert b_shallow != b_deep
    # and the closed form tracks the actual truncated-client param count
    assert (b_shallow - b_deep) == pytest.approx(
        2.0 * 32.0 * shallow.num_clients * (n_shallow - n_deep))


@pytest.mark.parametrize("depth", (0, 3, -1))
def test_cut_depth_out_of_range(depth):
    bad = dataclasses.replace(CFG, conv_channels=(4, 8), cut_depth=depth)
    with pytest.raises(ValueError, match="cut_depth"):
        splitfed_lib.client_cfg(bad)


# ---------------------------------------------------------------------------
# hybrid_fl_clients
# ---------------------------------------------------------------------------

def test_hybrid_fl_clients_validation():
    all_fl = dataclasses.replace(
        CFG, hybrid_fl_clients=tuple(range(CFG.num_clients)))
    with pytest.raises(ValueError, match="cut"):
        hybrid_lib.cut_mask(all_fl)
    with pytest.raises(ValueError, match="hybrid_fl_clients"):
        hybrid_lib.cut_mask(
            dataclasses.replace(CFG, hybrid_fl_clients=(CFG.num_clients,)))
    mask = hybrid_lib.cut_mask(CFG)              # default: client 0 is FL
    assert mask.shape == (CFG.num_clients,)
    assert not mask[0] and mask[1:].all()


def test_hybrid_mix_changes_ledger():
    """Moving a client from cut-mode to weight-mode swaps activation
    traffic for weight traffic on its edge — the ledgers must move."""
    scheme = schemes.get("hybrid")
    one_fl = CFG
    two_fl = dataclasses.replace(CFG, hybrid_fl_clients=(0, 1))
    s1 = scheme.init(one_fl, jax.random.PRNGKey(0))
    s2 = scheme.init(two_fl, jax.random.PRNGKey(0))
    l1 = scheme.edge_ledger(one_fl, s1, BATCH)
    l2 = scheme.edge_ledger(two_fl, s2, BATCH)
    assert l1.keys() == l2.keys()
    assert l1 != l2


# ---------------------------------------------------------------------------
# linkfault: perfect links invisible, lossy links degrade (not crash)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", HYBRIDS)
def test_perfect_star_bitwise_identity(name):
    want = trajectory(name)
    views, labels = fixture_data()
    scheme = schemes.get(name)
    perfect = linkfault.with_links(T.star(CFG.num_clients), PERFECT)
    state = scheme.init(CFG, jax.random.PRNGKey(0))
    round_fn = scheme.make_round(CFG, topology=perfect)
    v = jnp.broadcast_to(views[None, :, :BATCH],
                         (1,) + views[:, :BATCH].shape)
    lab = jnp.broadcast_to(labels[None, :BATCH], (1, BATCH))
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    np.testing.assert_array_equal(np.asarray(losses),
                                  np.asarray(want["losses"]),
                                  err_msg=f"{name}: perfect links moved "
                                          f"the losses")
    for g, w in zip(jax.tree.leaves(state),
                    jax.tree.leaves(want["state"])):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.parametrize("name", HYBRIDS)
def test_lossy_training_degrades_not_crashes(name):
    views, labels = fixture_data()
    lossy = linkfault.with_links(T.star(CFG.num_clients), LOSSY)
    meter = bandwidth.BandwidthMeter()
    curve = schemes.runner.run_scheme(
        name, views, labels, CFG, epochs=1, batch_size=BATCH,
        eval_n=64, topology=lossy, meter=meter)
    pt = curve[-1]
    assert np.isfinite(pt.accuracy)
    # the delivered ledger records the erasures the offered one ignores
    assert pt.delivered_gbits < pt.gbits
    assert meter.delivered_bits < meter.total_bits


# ---------------------------------------------------------------------------
# checkpoint resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", HYBRIDS)
def test_checkpoint_resume_bitwise(name, tmp_path):
    views, labels = fixture_data()
    kw = dict(epochs=2, batch_size=BATCH, eval_n=64)
    full = schemes.runner.run_scheme(name, views, labels, CFG, **kw)
    ck = tmp_path / name
    schemes.runner.run_scheme(name, views, labels, CFG, epochs=1,
                              batch_size=BATCH, eval_n=64,
                              ckpt_dir=str(ck))
    res = schemes.runner.run_scheme(name, views, labels, CFG, **kw,
                                    ckpt_dir=str(ck), resume=True)
    assert [p.accuracy for p in res] == [p.accuracy for p in full]
    assert [p.gbits for p in res] == [p.gbits for p in full]
