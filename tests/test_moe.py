"""MoE dispatch properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.configs import get_smoke_config
from repro.models import moe, zoo


def _cfg(cf=8.0, name="arctic-480b"):
    cfg = dataclasses.replace(get_smoke_config(name), dtype="float32")
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=cf))


def _moe_params(cfg, seed=0):
    return moe.moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32)


@pytest.mark.slow
def test_capacity_paths_match_when_droppless():
    """With capacity >= E/k * k (no drops possible) the buffer dispatch must
    equal the dense-gather decode path exactly."""
    cfg = _cfg(cf=8.0)
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 31, cfg.d_model))
    y1, _ = moe.moe_apply(p, cfg, x)
    y2, _ = moe.moe_decode_apply(p, cfg, x)
    np.testing.assert_allclose(y1, y2, atol=1e-5, rtol=1e-5)


@pytest.mark.slow
def test_dropping_is_order_preserving():
    """Dropping a LATER token never changes an EARLIER token's output
    (slot ranks are causal in token order)."""
    cfg = _cfg(cf=1.0)
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model))
    y_full, _ = moe.moe_apply(p, cfg, x)
    y_head, _ = moe.moe_apply(p, cfg, x[:, :16])
    cap_full = moe.expert_capacity(32, cfg.moe)
    cap_head = moe.expert_capacity(16, cfg.moe)
    if cap_full == cap_head:        # identical capacity -> exact prefix match
        np.testing.assert_allclose(y_full[:, :16], y_head, atol=1e-5)


def test_load_balance_loss_bounds():
    """lb_loss == E * sum(f_e p_e) >= 1 at uniform routing, z_loss >= 0."""
    cfg = _cfg()
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    _, aux = moe.moe_apply(p, cfg, x)
    assert float(aux["lb_loss"]) >= 0.99   # >= 1 in expectation
    assert float(aux["z_loss"]) >= 0.0
    np.testing.assert_allclose(float(aux["expert_load"].sum()), 1.0,
                               atol=1e-5)


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 10), k=st.sampled_from([1, 2, 3]))
def test_topk_weights_normalised(seed, k):
    cfg = _cfg()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, experts_per_token=k))
    p = _moe_params(cfg, seed=seed % 3)
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 16, cfg.d_model))
    y, aux = moe.moe_apply(p, cfg, x)
    assert bool(jnp.isfinite(y).all())


@pytest.mark.slow
def test_shared_experts_always_active():
    """DeepSeek-style shared experts contribute even when routed experts
    drop everything (capacity ~ 0)."""
    cfg = _cfg(name="deepseek-v2-236b", cf=1e-9)
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model))
    y, _ = moe.moe_apply(p, cfg, x)
    assert float(jnp.max(jnp.abs(y))) > 0.0


def test_active_param_count_less_than_total():
    cfg = get_smoke_config("deepseek-v2-236b")
    assert zoo.param_count(cfg, active_only=True) < zoo.param_count(cfg)


@pytest.mark.slow
def test_ep_falls_back_without_mesh():
    """moe_apply_ep on a mesh-less CPU must equal moe_apply exactly."""
    cfg = _cfg(cf=8.0, name="deepseek-v2-236b")
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 16, cfg.d_model))
    y1, _ = moe.moe_apply(p, cfg, x)
    y2, _ = moe.moe_apply_ep(p, cfg, x)
    np.testing.assert_allclose(y1, y2, atol=0)
