"""The multi-process worker plane's contracts (repro/cluster,
transport/adaptive, and the serving plane's admission control).

The supervision ladder and the adaptive controller are PURE state
machines — functions of (tick, observation) with no processes, sockets,
or wall clock — so the miss-threshold -> suspect -> dead ->
restart-backoff -> rejoin ladder and the knob trajectories pin down as
plain units.  The real-process section then spawns actual workers and
asserts the same ladder over genuine SIGKILL/SIGSTOP:

  * spawn + handshake: the worker registers, echoes bytes bit-exactly
    across two process boundaries, and answers pings;
  * SIGKILL: unscheduled death walks down the ladder, pays capped
    exponential restart backoff, rejoins with a bumped incarnation;
  * SIGSTOP: a frozen worker goes suspect then dead via probe timeouts,
    and rejoins with the SAME incarnation on thaw (it never restarted);
  * serving admission control: a bounded queue sheds with a typed
    Rejected RESULT (not an exception), and graceful shutdown fails
    still-pending futures with EngineShutdown so no waiter hangs.
"""
import numpy as np
import pytest

from repro.cluster import (DOWN, SUSPECT, UP, HeartbeatMonitor, Supervisor)
from repro.core import schemes
from repro.core import topology as topology_lib
from repro.serving import EngineShutdown, Rejected, ServingEngine
from repro.transport import AdaptiveConfig, AdaptivePolicy, DEFAULT_RETRY
from repro.transport.policy import RetryPolicy
from tests._schemes_common import CFG, fixture_data, trajectory


# ---------------------------------------------------------------------------
# membership ladder (pure)
# ---------------------------------------------------------------------------

def _monitor(**kw):
    kw.setdefault("seed", 0)
    kw.setdefault("interval", 1)
    kw.setdefault("suspect_after", 1)
    kw.setdefault("dead_after", 3)
    kw.setdefault("backoff_base", 2)
    kw.setdefault("backoff_mult", 2)
    kw.setdefault("backoff_cap", 8)
    kw.setdefault("stable_after", 2)
    m = HeartbeatMonitor(["a"], **kw)
    m.note_joined("a", 0)
    return m


def test_miss_ladder_up_suspect_dead():
    m = _monitor()
    assert m.view().mask(["a"]).tolist() == [True]
    m.observe("a", 1, False)
    assert m.nodes["a"].status == SUSPECT
    assert m.view().mask(["a"]).tolist() == [True]   # suspects keep voting
    m.observe("a", 2, False)
    assert m.nodes["a"].status == SUSPECT            # dead_after=3 not hit
    m.observe("a", 3, False)
    assert m.nodes["a"].status == DOWN
    assert m.is_down("a") and not m.view().mask(["a"]).any()
    assert [e[2] for e in m.events] == \
        ["down->up", "up->suspect", "suspect->down"]


def test_pong_clears_misses_and_rejoins_frozen_node_in_place():
    m = _monitor()
    for t in (1, 2, 3):
        m.observe("a", t, False)
    assert m.nodes["a"].status == DOWN
    inc = m.nodes["a"].incarnation
    m.observe("a", 4, True)             # it answered: frozen, not dead
    assert m.nodes["a"].status == UP
    assert m.nodes["a"].incarnation == inc          # same incarnation
    assert m.nodes["a"].restart_due is None         # no restart pending


def test_scheduled_exit_restarts_at_window_end():
    m = _monitor()
    m.note_exit("a", 5, scheduled=True)
    assert m.nodes["a"].status == DOWN
    assert m.due_restart("a", 5)         # the schedule owns the timing
    assert m.nodes["a"].backoff_level == 0          # no backoff charged


def test_unscheduled_exit_backoff_escalates_then_caps():
    m = _monitor(backoff_base=2, backoff_mult=2, backoff_cap=8)
    due = []
    t = 0
    for crash in range(4):
        m.note_exit("a", t, scheduled=False)
        due.append(m.nodes["a"].restart_due - t)
        assert not m.due_restart("a", t + due[-1] - 1)
        assert m.due_restart("a", t + due[-1])
        t += due[-1]
        m.note_joined("a", t)
    assert due == [2, 4, 8, 8]           # base * mult**level, capped


def test_stability_decays_backoff_level():
    m = _monitor(stable_after=2)
    m.note_exit("a", 0, scheduled=False)
    m.note_joined("a", 2)
    assert m.nodes["a"].backoff_level == 1
    m.tick_stability(3)
    assert m.nodes["a"].backoff_level == 1          # not stable yet
    m.tick_stability(4)
    assert m.nodes["a"].backoff_level == 0          # 2 up-ticks: decayed


def test_rejoin_bumps_incarnation_and_version():
    m = _monitor()
    v0 = m.view().version
    m.note_exit("a", 1, scheduled=False)
    m.note_joined("a", 3)
    view = m.view()
    assert dict(view.incarnations)["a"] == 2
    assert view.version > v0
    assert m.nodes["a"].restarts == 1


def test_beat_phases_seeded_and_replayable():
    nodes = [f"m{i}" for i in range(8)]
    a = HeartbeatMonitor(nodes, seed=7, interval=4)
    b = HeartbeatMonitor(nodes, seed=7, interval=4)
    for n in nodes:
        assert [a.beat_due(n, t) for t in range(16)] == \
            [b.beat_due(n, t) for t in range(16)]
        assert sum(a.beat_due(n, t) for t in range(4)) == 1
    c = HeartbeatMonitor(nodes, seed=8, interval=4)
    assert any([a.beat_due(n, t) for t in range(16)]
               != [c.beat_due(n, t) for t in range(16)] for n in nodes)


def test_dead_after_validation():
    with pytest.raises(ValueError):
        HeartbeatMonitor(["a"], suspect_after=3, dead_after=2)


# ---------------------------------------------------------------------------
# adaptive fault policies (pure)
# ---------------------------------------------------------------------------

def test_adaptive_tightens_on_low_ratio_and_floors():
    pol = AdaptivePolicy(base=RetryPolicy(max_attempts=3), base_threshold=3,
                         config=AdaptiveConfig(window=4))
    for _ in range(3 * 4):               # three windows of pure loss
        pol.observe("e", offered=3.0, delivered=0.0)
    assert pol.policy_for("e").max_attempts == 1    # floored, not 0
    assert pol.threshold_for("e") == 1
    assert pol.retunes == 3


def test_adaptive_relaxes_back_to_base_and_ceilings():
    pol = AdaptivePolicy(base=RetryPolicy(max_attempts=3), base_threshold=3,
                         config=AdaptiveConfig(window=2))
    for _ in range(2 * 2):
        pol.observe("e", offered=3.0, delivered=0.0)
    assert pol.policy_for("e").max_attempts == 1
    for _ in range(6 * 2):               # healthy windows walk back up
        pol.observe("e", offered=1.0, delivered=1.0)
    assert pol.policy_for("e") is pol.base          # back at base: identity
    assert pol.threshold_for("e") == 3


def test_adaptive_holds_when_nothing_offered():
    pol = AdaptivePolicy(base=RetryPolicy(max_attempts=3), base_threshold=3,
                         config=AdaptiveConfig(window=2))
    for _ in range(4):                   # breaker short-circuited the window
        pol.observe("e", offered=0.0, delivered=0.0)
    assert pol.policy_for("e").max_attempts == 3    # uninformative: hold
    assert pol.retunes == 2              # the window still closed


def test_adaptive_midband_holds_knobs():
    pol = AdaptivePolicy(base=RetryPolicy(max_attempts=3), base_threshold=3,
                         config=AdaptiveConfig(window=2, ratio_low=0.5,
                                               ratio_high=0.9))
    for _ in range(4):                   # ratio 0.7: between the rails
        pol.observe("e", offered=1.0, delivered=0.7)
    assert pol.policy_for("e").max_attempts == 3


def test_adaptive_state_roundtrip_resumes_mid_window():
    a = AdaptivePolicy(base=DEFAULT_RETRY, base_threshold=3,
                       config=AdaptiveConfig(window=4))
    for i in range(6):                   # one retune + half an open window
        a.observe("e", offered=2.0, delivered=0.0)
    b = AdaptivePolicy(base=DEFAULT_RETRY, base_threshold=3,
                       config=AdaptiveConfig(window=4))
    b.load_state_dict(a.state_dict())
    for p in (a, b):
        p.observe("e", offered=2.0, delivered=0.0)
        p.observe("e", offered=2.0, delivered=0.0)
    assert a.state_dict() == b.state_dict()
    assert a.policy_for("e").max_attempts == b.policy_for("e").max_attempts


# ---------------------------------------------------------------------------
# serving admission control + graceful shutdown
# ---------------------------------------------------------------------------

def _engine(**kw):
    scheme = schemes.get("inl")
    state = trajectory("inl")["state"]
    views, _ = fixture_data()
    return ServingEngine(scheme, state, CFG, seed=5, **kw), np.asarray(views)


def test_bounded_queue_sheds_with_typed_rejected():
    engine, views = _engine(max_queue=2)
    engine.warmup()
    futs = [engine.submit(views[:, i])[1] for i in range(5)]
    shed = [f for f in futs if f.done() and isinstance(f.result(), Rejected)]
    assert len(shed) == 3 and engine.stats.shed == 3
    assert all(r.result().reason for r in shed)     # typed, with a reason
    while engine.pending():
        engine.step()
    served = [f.result() for f in futs if not isinstance(f.result(),
                                                         Rejected)]
    assert len(served) == 2 and all(r.probs.shape[-1] == 10 for r in served)


def test_unbounded_queue_never_sheds():
    engine, views = _engine()
    engine.warmup()
    futs = [engine.submit(views[:, i])[1] for i in range(5)]
    while engine.pending():
        engine.step()
    assert engine.stats.shed == 0
    assert all(not isinstance(f.result(), Rejected) for f in futs)


def test_shutdown_fails_pending_futures_and_refuses_new_submits():
    engine, views = _engine()
    engine.warmup()
    futs = [engine.submit(views[:, i])[1] for i in range(3)]
    engine.shutdown(drain_timeout=0.0)   # no drain budget: fail them all
    for f in futs:
        with pytest.raises(EngineShutdown):
            f.result(timeout=1.0)
    with pytest.raises(EngineShutdown):
        engine.submit(views[:, 0])
    engine.shutdown()                    # idempotent


def test_shutdown_with_budget_drains_then_stops():
    engine, views = _engine()
    engine.warmup()
    futs = [engine.submit(views[:, i])[1] for i in range(3)]
    engine.shutdown(drain_timeout=30.0)
    assert all(f.done() for f in futs)
    assert all(not isinstance(f.result(), Rejected) for f in futs)
    assert engine.pending() == 0


# ---------------------------------------------------------------------------
# real worker processes: spawn, echo, SIGKILL, SIGSTOP
# ---------------------------------------------------------------------------

def _supervisor(**kw):
    kw.setdefault("seed", 0)
    kw.setdefault("heartbeat_interval", 1)
    kw.setdefault("suspect_after", 1)
    kw.setdefault("dead_after", 2)
    kw.setdefault("backoff_base", 2)
    kw.setdefault("io_timeout", 0.2)
    return Supervisor(["m0", "m1"], **kw)


def test_workers_spawn_register_and_echo_bit_exact():
    with _supervisor() as sup:
        pids = {n: h.proc.pid for n, h in sup.handles.items()}
        assert len(set(pids.values())) == 2         # two real processes
        topo = topology_lib.star(2)
        chans = sup.edge_channels(topo)
        assert set(chans) == {e.key for e in topo.edges}
        payload = np.random.default_rng(0).bytes(4096)
        chan = next(iter(chans.values()))
        chan.send(payload)
        assert chan.recv(5.0) == payload            # crossed two boundaries
        sup.tick(0)
        sup.tick(1)
        assert sup.membership().mask(["m0", "m1"]).all()


def test_sigkill_walks_ladder_pays_backoff_and_rejoins():
    with _supervisor(backoff_base=2) as sup:
        sup.tick(0)
        sup.kill("m1")                   # UNSCHEDULED: backoff applies
        sup.tick(1)                      # reaped: down, restart due at 3
        assert sup.is_down("m1")
        assert not sup.is_down("m0")     # healthy nodes keep their vote
        assert not sup.membership().mask(["m0", "m1"])[1]
        sup.tick(2)
        assert sup.is_down("m1")         # backoff not elapsed
        sup.tick(3)                      # due: respawned
        assert not sup.is_down("m1")
        view = sup.membership()
        assert dict(view.incarnations)["m1"] == 2
        assert sup.respawns == 1
        assert ("up->down" in [e[2] for e in sup.events() if e[1] == "m1"])


def test_sigstop_suspect_dead_then_thaw_rejoins_same_incarnation():
    # the freeze rides the chaos schedule: tick() realises the window with
    # a real SIGSTOP and thaws with SIGCONT when it closes (a manual
    # freeze() outside any window would be reconciled away next tick)
    from repro.chaos import ChaosSchedule
    chaos = ChaosSchedule().freeze_node("m0", at=1, duration=2)
    with _supervisor(dead_after=2, chaos=chaos) as sup:
        sup.tick(0)
        sup.tick(1)                      # SIGSTOP; probe times out: suspect
        assert sup.monitor.nodes["m0"].status == SUSPECT
        assert sup.handles["m0"].frozen
        assert sup.membership().mask(["m0", "m1"])[0]   # suspects vote
        sup.tick(2)                      # second miss: dead
        assert sup.is_down("m0")
        sup.tick(3)                      # window closed: SIGCONT, pong
        assert not sup.is_down("m0")
        assert dict(sup.membership().incarnations)["m0"] == 1
        assert sup.respawns == 0         # it never restarted


def test_is_down_ignores_unowned_nodes():
    with _supervisor() as sup:
        assert not sup.is_down("fuse")   # not ours: never down on our account
