"""repro/checkpoint's contracts: lossless round trips, loud mismatches,
crash-atomic writes.

  * pytree round trip preserves structure, values, and dtypes — including
    bf16, which stores as fp32 (npz has no bf16) and round-trips BITWISE;
  * `latest_step` orders numerically and only counts COMPLETE checkpoints
    (npz + JSON sidecar — the sidecar lands last, atomically);
  * restore into a template with a different structure, shape, or dtype
    fails LOUDLY (a bf16 checkpoint cannot silently cast into an fp32
    config);
  * a save interrupted mid-write (the repro/chaos.py SIGKILL) leaves no
    torn checkpoint visible to resume.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import checkpoint


def _tree():
    return {
        "params": {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
                   "b": np.ones(4, np.float32)},
        "step": np.asarray(7, np.int32),
        "nested": [np.full((2,), 0.5, np.float32)],
    }


def test_roundtrip_preserves_values_and_structure(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    checkpoint.save(d, 3, tree, extra={"note": "hi"})
    got, step = checkpoint.restore(d, tree)
    assert step == 3
    flat_a = jax.tree_util.tree_flatten(tree)
    flat_b = jax.tree_util.tree_flatten(jax.device_get(got))
    assert flat_a[1] == flat_b[1]                  # same treedef
    for a, b in zip(flat_a[0], flat_b[0]):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.load_meta(d)["note"] == "hi"


def test_bf16_roundtrip_is_bitwise_lossless(tmp_path):
    d = str(tmp_path)
    # every finite bf16 value is exactly representable in fp32, so the
    # bf16 -> fp32 (npz) -> bf16 trip must be the identity on bit patterns
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(257) * 1e3, jnp.bfloat16)
    tree = {"w": x}
    checkpoint.save(d, 1, tree)
    got, _ = checkpoint.restore(d, tree)
    assert got["w"].dtype == jnp.bfloat16
    assert np.array_equal(np.asarray(got["w"]).view(np.uint16),
                          np.asarray(x).view(np.uint16))
    # the sidecar remembers the ORIGINAL dtype, not the storage dtype
    assert checkpoint.load_meta(d)["dtypes"]["w"] == "bfloat16"


def test_latest_step_numeric_ordering(tmp_path):
    d = str(tmp_path)
    assert checkpoint.latest_step(d) is None
    for s in (2, 10, 9):                           # lexicographic would say 9
        checkpoint.save(d, s, {"x": np.zeros(1, np.float32)})
    assert checkpoint.latest_step(d) == 10
    got, step = checkpoint.restore(d, {"x": np.zeros(1, np.float32)})
    assert step == 10


def test_latest_step_ignores_sidecarless_npz(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, {"x": np.zeros(1, np.float32)})
    # a crash between the npz replace and the sidecar replace: the npz
    # exists but the checkpoint is incomplete -> invisible to resume
    with open(os.path.join(d, "ckpt_00000009.npz"), "wb") as f:
        f.write(b"torn")
    assert checkpoint.latest_step(d) == 1


def test_no_tmp_files_left_behind(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 4, _tree())
    assert not [fn for fn in os.listdir(d) if fn.endswith(".tmp")]


def test_structure_mismatch_is_loud(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, {"a": np.zeros(2, np.float32)})
    with pytest.raises(ValueError, match="missing|extra"):
        checkpoint.restore(d, {"b": np.zeros(2, np.float32)})


def test_shape_mismatch_is_loud(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, {"a": np.zeros((2, 3), np.float32)})
    with pytest.raises(ValueError, match="shape"):
        checkpoint.restore(d, {"a": np.zeros((3, 2), np.float32)})


def test_dtype_mismatch_refuses_silent_cast(tmp_path):
    d = str(tmp_path)
    checkpoint.save(d, 1, {"a": jnp.zeros(4, jnp.bfloat16)})
    with pytest.raises(ValueError, match="refusing the silent cast"):
        checkpoint.restore(d, {"a": np.zeros(4, np.float32)})


def test_predtype_checkpoints_still_restore(tmp_path):
    # checkpoints written before dtypes were recorded skip the dtype check
    d = str(tmp_path)
    checkpoint.save(d, 1, {"a": np.zeros(4, np.float32)})
    meta_path = os.path.join(d, "ckpt_00000001.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["dtypes"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    got, _ = checkpoint.restore(d, {"a": np.zeros(4, np.float32)})
    assert np.array_equal(np.asarray(got["a"]), np.zeros(4, np.float32))


def test_restore_empty_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        checkpoint.restore(str(tmp_path), {"a": np.zeros(1, np.float32)})
    with pytest.raises(FileNotFoundError):
        checkpoint.load_meta(str(tmp_path))
