"""The int8 wire (beyond-paper ICI compression) and chunk-remat scans."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import linkmodel


def test_wire_concat_matches_float_concat_within_grid():
    """Quantization error bounded by half a grid step inside the clip range
    (|u| <= 4 sigma); clipped outliers err by at most their overshoot.
    Layout identical."""
    u = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 5, 8)) * 1.5
    cat8 = linkmodel.wire_concat(u)
    catf = linkmodel.float_concat(u)
    assert cat8.shape == catf.shape
    step = 2 * 4.0 / 254
    err = jnp.abs(cat8 - catf)
    in_range = jnp.abs(catf) <= 4.0 - step
    assert float(jnp.max(jnp.where(in_range, err, 0.0))) <= step / 2 + 1e-6
    overshoot = jnp.maximum(jnp.abs(catf) - 4.0, 0.0)
    assert float(jnp.max(err - overshoot)) <= step / 2 + 1e-6


def test_wire_concat_backward_is_error_split():
    """The VJP must route chunk j of the decoder-input cotangent to node j
    (eq. 8c), with straight-through (near-identity) magnitude."""
    J, B, S, db = 3, 2, 4, 8
    u = jax.random.normal(jax.random.PRNGKey(1), (J, B, S, db))
    w = jax.random.normal(jax.random.PRNGKey(2), (J * db,))

    def f(u_):
        return (linkmodel.wire_concat(u_) * w).sum()

    du = jax.grad(f)(u)
    # reference: the float path's exact split
    du_ref = jax.grad(lambda u_: (linkmodel.float_concat(u_) * w).sum())(u)
    # int8 backward link: equal up to the dynamic quantization grid
    gmax = float(jnp.max(jnp.abs(du_ref)))
    assert float(jnp.max(jnp.abs(du - du_ref))) <= gmax / 127 + 1e-6


def test_wire_concat_quantizes_backward_link():
    """Backward cotangents pass through a 255-level grid."""
    J, B, S, db = 2, 1, 2, 4
    u = jnp.zeros((J, B, S, db))
    g = jax.random.normal(jax.random.PRNGKey(3), (B, S, J * db))
    _, vjp = jax.vjp(lambda x: linkmodel.wire_concat(x), u)
    (du,) = vjp(g)
    vals = np.unique(np.round(np.asarray(du), 10))
    assert len(vals) <= 255 * 2


@pytest.mark.parametrize("bits", [2, 4])
def test_packed_wire_concat_matches_float_within_grid(bits):
    """The sub-byte packed wire: quantization error bounded by half a step
    of the bits-level grid inside the clip range, layout identical to the
    float concat (the int8 wire's contract at sub-byte widths)."""
    u = jax.random.normal(jax.random.PRNGKey(4), (3, 2, 5, 8)) * 1.5
    catp = linkmodel.packed_wire_concat(u, bits)
    catf = linkmodel.float_concat(u)
    assert catp.shape == catf.shape
    step = 2 * 4.0 / ((1 << bits) - 1)
    err = jnp.abs(catp - catf)
    in_range = jnp.abs(catf) <= 4.0 - step
    assert float(jnp.max(jnp.where(in_range, err, 0.0))) <= step / 2 + 1e-6
    # and it really is the shared quantizer grid (kernels/ref semantics;
    # atol covers the 1-ulp jit-vs-eager constant-folding drift of x/scale)
    from repro.kernels import ref
    want = linkmodel.float_concat(ref.quantize_value(u, bits))
    np.testing.assert_allclose(np.asarray(catp), np.asarray(want),
                               atol=1e-6)


def test_packed_wire_concat_backward_is_quantized_error_split():
    """VJP routes chunk j of the cotangent to node j, quantized on a
    dynamic (2^bits - 1)-level grid — the packed backward link."""
    J, B, S, db, bits = 3, 2, 4, 8, 4
    u = jax.random.normal(jax.random.PRNGKey(5), (J, B, S, db))
    w = jax.random.normal(jax.random.PRNGKey(6), (J * db,))

    du = jax.grad(lambda u_: (linkmodel.packed_wire_concat(u_, bits)
                              * w).sum())(u)
    du_ref = jax.grad(lambda u_: (linkmodel.float_concat(u_) * w).sum())(u)
    gmax = float(jnp.max(jnp.abs(du_ref)))
    step = 2 * gmax / ((1 << bits) - 1)
    assert float(jnp.max(jnp.abs(du - du_ref))) <= step / 2 + 1e-6
    vals = np.unique(np.round(np.asarray(du), 10))
    assert len(vals) <= (1 << bits)                 # on the coarse grid


def test_chunked_remat_scan_matches_plain():
    from repro.models.ssm import _scan_chunked_remat

    def cell(c, x):
        c = 0.9 * c + x
        return c, jnp.tanh(c)

    S = 64
    xs = jax.random.normal(jax.random.PRNGKey(4), (S, 8))

    def loss_plain(xs_):
        _, ys = jax.lax.scan(cell, jnp.zeros(8), xs_)
        return (ys ** 2).sum()

    def loss_chunked(xs_):
        _, ys = _scan_chunked_remat(cell, jnp.zeros(8), xs_, S, 16)
        return (ys ** 2).sum()

    np.testing.assert_allclose(float(loss_plain(xs)),
                               float(loss_chunked(xs)), rtol=1e-6)
    g1 = jax.grad(loss_plain)(xs)
    g2 = jax.grad(loss_chunked)(xs)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


def test_chunked_remat_fallback_non_divisible():
    from repro.models.ssm import _scan_chunked_remat

    def cell(c, x):
        return c + x, c

    xs = jnp.ones((10, 2))
    c, ys = _scan_chunked_remat(cell, jnp.zeros(2), xs, 10, 4)  # 10 % 4 != 0
    np.testing.assert_allclose(np.asarray(c), 10.0)
