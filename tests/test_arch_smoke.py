"""Deliverable (f): per-architecture smoke tests — instantiate the REDUCED
variant of each assigned family and run one forward + one train step on CPU,
asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import ShapeConfig, get_smoke_config, list_archs
from repro.launch import steps as steps_lib
from repro.models import zoo

SHAPE = ShapeConfig("smoke", 64, 2, "train")


def _cfg(name):
    return dataclasses.replace(get_smoke_config(name), dtype="float32")


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(list_archs()))
def test_forward_shapes_and_finite(name):
    cfg = _cfg(name)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    batch = zoo.dummy_batch(cfg, SHAPE)
    logits, _, aux = zoo.forward(params, cfg, batch, mode="train")
    B, S = SHAPE.global_batch, SHAPE.seq_len
    if cfg.modality == "audio_tokens":
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name}: non-finite logits"


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(list_archs()))
def test_train_step_no_nan(name):
    cfg = _cfg(name)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.adamw(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(cfg, opt))
    batch = zoo.dummy_batch(cfg, SHAPE)
    new_params, _, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), f"{name}: NaN loss"
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(moved)) > 0.0


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(list_archs()))
def test_param_count_matches_init(name):
    cfg = _cfg(name)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    n_init = sum(x.size for x in jax.tree.leaves(params))
    assert n_init == zoo.param_count(cfg)


@pytest.mark.slow
def test_microbatched_step_matches_full():
    """Gradient accumulation must be arithmetically equivalent (CE is a mean
    over tokens, all microbatches have equal token counts here)."""
    cfg = _cfg("llama3.2-1b")
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.sgd(1e-2)
    batch = zoo.dummy_batch(cfg, ShapeConfig("s", 32, 4, "train"))
    p1, _, m1 = steps_lib.make_train_step(cfg, opt)(params, opt.init(params),
                                                    batch)
    p2, _, m2 = steps_lib.make_train_step(cfg, opt, microbatches=2)(
        params, opt.init(params), batch)
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)
    assert max(jax.tree.leaves(diff)) < 2e-5
