"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(deliverable c).  Each kernel also gets a hypothesis property pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.inl_bottleneck import bottleneck_fused
from repro.kernels.ssm_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,Dh,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),        # MHA
    (2, 256, 8, 2, 64, 128, 128),      # GQA 4:1
    (1, 256, 8, 1, 64, 128, 64),       # MQA
    (1, 512, 2, 2, 128, 128, 256),     # MXU-width heads
])
def test_flash_attention_sweep(B, S, H, KV, Dh, bq, bk, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (B, S, H, Dh), dtype)
    k = jax.random.normal(k2, (B, S, KV, Dh), dtype)
    v = jax.random.normal(k3, (B, S, KV, Dh), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [32, 100, 256])
def test_flash_attention_window(window):
    B, S, H, KV, Dh = 1, 256, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, KV, Dh))
    v = jax.random.normal(ks[2], (B, S, KV, Dh))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


def test_flash_attention_q_offset():
    """Chunked prefill: q at positions [64:128) attending to k[0:128)."""
    B, S, H, KV, Dh = 1, 128, 2, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, S, H, Dh))
    k = jax.random.normal(ks[1], (B, S, KV, Dh))
    v = jax.random.normal(ks[2], (B, S, KV, Dh))
    out = flash_attention(q[:, 64:], k, v, causal=True, q_offset=64,
                          block_q=64, block_k=64)
    want = ref.attention_ref(q, k, v, causal=True)[:, 64:]
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# INL bottleneck fusion
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,d,bt", [(256, 64, 64), (512, 128, 256),
                                    (1024, 32, 1024)])
def test_bottleneck_sweep(T, d, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    mu = jax.random.normal(ks[0], (T, d), dtype)
    lv = (jax.random.normal(ks[1], (T, d)) * 0.3).astype(dtype)
    eps = jax.random.normal(ks[2], (T, d), dtype)
    u, kl = bottleneck_fused(mu, lv, eps, block_t=bt)
    u_ref, kl_ref = ref.bottleneck_ref(mu, lv, eps)
    np.testing.assert_allclose(u.astype(jnp.float32),
                               u_ref.astype(jnp.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])
    np.testing.assert_allclose(kl, kl_ref, atol=5e-2, rtol=1e-2)


@settings(max_examples=20, deadline=None)
@given(t_blocks=st.integers(1, 4), d=st.sampled_from([16, 64, 96]),
       seed=st.integers(0, 2 ** 16))
def test_bottleneck_property(t_blocks, d, seed):
    """KL >= 0 and u == mu when eps == 0, for arbitrary mu/logvar."""
    T = 64 * t_blocks
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    mu = jax.random.normal(ks[0], (T, d))
    lv = jnp.clip(jax.random.normal(ks[1], (T, d)), -4, 2)
    u, kl = bottleneck_fused(mu, lv, jnp.zeros((T, d)), block_t=64)
    assert bool((kl >= -1e-4).all())
    np.testing.assert_allclose(u, mu, atol=1e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 128, 2, 32, 16, 32),
    (2, 256, 4, 64, 64, 128),
    (1, 192, 2, 16, 8, 64),            # S a non-power-of-two multiple
])
@pytest.mark.slow
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N), dtype)
    cm = jax.random.normal(ks[4], (B, S, N), dtype)
    d = jnp.ones((H,))
    y = ssd_scan(x, dt, a, bm, cm, d, chunk=chunk)
    want = ref.ssd_scan_ref(x, dt, a, bm, cm, d)
    scale = float(jnp.max(jnp.abs(want.astype(jnp.float32)))) + 1e-6
    err = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - want.astype(jnp.float32)))) / scale
    assert err < (2e-2 if dtype == jnp.bfloat16 else 2e-5), err


@pytest.mark.slow
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), chunk=st.sampled_from([16, 32, 64]))
def test_ssd_chunk_invariance(seed, chunk):
    """The chunked kernel must be invariant to the chunk size."""
    B, S, H, P, N = 1, 128, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(ks[4], (B, S, N))
    d = jnp.zeros((H,))
    y1 = ssd_scan(x, dt, a, bm, cm, d, chunk=chunk)
    y2 = ssd_scan(x, dt, a, bm, cm, d, chunk=S)
    np.testing.assert_allclose(y1, y2, atol=5e-4, rtol=1e-4)


def test_model_ssd_matches_kernel():
    """models/ssm.py's chunked jnp SSD == the Pallas kernel contract."""
    from repro.models.ssm import _ssd_chunked
    B, S, H, P, N = 2, 128, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    bm = jax.random.normal(ks[3], (B, S, N))
    cm = jax.random.normal(ks[4], (B, S, N))
    d = jnp.ones((H,))
    y1, _ = _ssd_chunked(x, dt, a, bm, cm, d, 64)
    y2 = ssd_scan(x, dt, a, bm, cm, d, chunk=64)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=1e-4)
