"""Shared fixture logic for the scheme-parity and golden-metric tests.

Compiling each scheme's round function is the dominant cost of these tests
(the FL round jits a vmap-over-clients lax.scan of the full Fig.-4 model),
so the deterministic training trajectories are computed ONCE per process
and shared: parity asserts qualitative properties (loss improves, predict
is a distribution), the golden test pins the exact numbers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_inl import PaperExperimentConfig
from repro.core import schemes
from repro.data import multiview

# Tier-1-sized: jit-compiling each scheme's round (FL: vmap-over-clients
# lax.scan of the full model) dominates the cost, so the fixture model is a
# single conv layer on 16x16 views — the Scheme contract and the training
# dynamics it pins do not need the paper-scale widths.
CFG = PaperExperimentConfig(conv_channels=(4,), d_bottleneck=8,
                            dense_units=(32,), image_shape=(16, 16, 3),
                            dataset_size=128)
BATCH = 32
ROUNDS = 6


@functools.lru_cache(maxsize=None)
def fixture_data():
    """Tiny deterministic multi-view set: (views (J,128,...), labels)."""
    imgs, labels = multiview.make_base_dataset(
        128, image_shape=CFG.image_shape, seed=0)
    views = multiview.make_views(imgs, CFG.noise_stds)
    return jnp.asarray(views), jnp.asarray(labels)


def round_inputs(scheme, cfg, views, labels):
    """One fixed minibatch stacked batches_per_round(cfg) times."""
    R = scheme.batches_per_round(cfg)
    v = jnp.broadcast_to(views[None, :, :BATCH],
                         (R,) + views[:, :BATCH].shape)
    lab = jnp.broadcast_to(labels[None, :BATCH], (R, BATCH))
    return v, lab


def trajectory(name: str, learned_prior: bool = False):
    """ROUNDS deterministic rounds of scheme `name` on the fixed batch.

    Returns {"losses": tuple, "final_accuracy": float} plus the trained
    state under "state" (not part of the golden record).  Cached per
    (name, learned_prior) — compiling each scheme's round dominates, so
    the parity and golden tests share one trajectory per scheme."""
    return _trajectory(name, bool(learned_prior))


@functools.lru_cache(maxsize=None)
def _trajectory(name: str, learned_prior: bool):
    import dataclasses
    cfg = dataclasses.replace(CFG, learned_prior=True) if learned_prior \
        else CFG
    views, labels = fixture_data()
    scheme = schemes.get(name)
    state = scheme.init(cfg, jax.random.PRNGKey(0))
    round_fn = scheme.make_round(cfg)
    v, lab = round_inputs(scheme, cfg, views, labels)
    losses = []
    for i in range(ROUNDS):
        state, metrics = round_fn(state, v, lab, jax.random.PRNGKey(i))
        losses.append(float(metrics["loss"]))
    probs = scheme.predict(state, views[:, :BATCH])
    acc = float((jnp.argmax(probs, -1) == labels[:BATCH]).mean())
    return {"losses": tuple(losses), "final_accuracy": acc, "state": state}
