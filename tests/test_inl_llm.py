"""INL applied to the assigned LLM architectures (core/inl_llm)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import get_smoke_config
from repro.core import inl_llm
from repro.models import transformer


def _cfg(name):
    cfg = dataclasses.replace(get_smoke_config(name), dtype="float32")
    pat = transformer.block_pattern(cfg)
    need = (cfg.inl.encoder_layers + 1) * len(pat) + cfg.moe.first_dense_layers
    if cfg.num_layers < need:
        cfg = dataclasses.replace(cfg, num_layers=need)
    return cfg


@pytest.mark.parametrize("name", ["llama3.2-1b", "zamba2-2.7b",
                                  "deepseek-v2-236b"])
@pytest.mark.slow
def test_inl_llm_loss_finite(name):
    cfg = _cfg(name)
    params = inl_llm.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab_size)}
    loss, metrics = inl_llm.loss_fn(params, cfg, batch, jax.random.PRNGKey(3))
    assert bool(jnp.isfinite(loss))
    assert metrics["bits_per_token"] == 2 * cfg.inl.num_nodes \
        * cfg.inl.d_bottleneck * cfg.inl.link_bits


@pytest.mark.slow
def test_inl_llm_eq5_decoder_width():
    cfg = _cfg("llama3.2-1b")
    params = inl_llm.init(cfg, jax.random.PRNGKey(0))
    w = params.decoder["in_proj"]["w"]
    assert w.shape[0] == cfg.inl.num_nodes * cfg.inl.d_bottleneck


@pytest.mark.slow
def test_inl_llm_train_step_updates():
    cfg = _cfg("llama3.2-1b")
    params = inl_llm.init(cfg, jax.random.PRNGKey(0))
    opt = optim.adam(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(inl_llm.make_train_step(cfg, opt))
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab_size)}
    p2, _, m = step(params, opt_state, batch, jax.random.PRNGKey(4))
    assert bool(jnp.isfinite(m["loss"]))
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2)
    assert max(jax.tree.leaves(delta)) > 0


def test_encoder_decoder_layer_split():
    cfg = _cfg("zamba2-2.7b")
    e, d = inl_llm.encoder_cfg(cfg), inl_llm.decoder_cfg(cfg)
    pat = len(transformer.block_pattern(cfg))
    assert e.num_layers + d.num_layers == \
        cfg.num_layers + cfg.moe.first_dense_layers * 0 \
        if cfg.moe.first_dense_layers == 0 else True
    assert e.num_layers == cfg.inl.encoder_layers * pat
