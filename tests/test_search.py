"""The auto-placement search subsystem (repro/search): space validity,
closed-form pricing parity, the two sound pruning rules, and Pareto
extraction — unit-level here; frontier_bench.py --smoke re-verifies the
pruning exhaustively (trains the pruned points too) on every CI leg.
"""
import dataclasses

import pytest
from _schemes_common import BATCH, CFG, fixture_data

from repro.core import bandwidth, schemes
from repro.core import topology as topology_lib
from repro.search import (ConfigPoint, SearchSpace, dominates,
                          pareto_frontier, price, run_search)
from repro.search.pareto import best_under_budget
from repro.search.pricing import CANDIDATE, PRUNED_STAR, PRUNED_WIRE
from repro.search.space import merge_points


# ---------------------------------------------------------------------------
# topology name parsing (core/topology.from_name / named_topologies)
# ---------------------------------------------------------------------------

def test_from_name_round_trips():
    assert topology_lib.from_name("star(5)").num_views() == 5
    assert topology_lib.from_name("chain(3)").num_views() == 3
    assert topology_lib.from_name("tree(2,2)").num_views() == 6


@pytest.mark.parametrize("bad", ["ring(4)", "star", "star(0)", "tree(2)",
                                 "chain(2,2)", "star(2,3)", ""])
def test_from_name_rejects(bad):
    with pytest.raises(ValueError):
        topology_lib.from_name(bad)


def test_named_topologies():
    topos = topology_lib.named_topologies(6)
    assert "star(6)" in topos and "chain(6)" in topos
    assert "tree(2,2)" in topos               # 2 + 4 = 6 views, two levels
    assert list(topology_lib.named_topologies(1)) == ["star(1)"]
    for name, topo in topology_lib.named_topologies(9).items():
        assert topo.num_views() == 9
        assert topology_lib.from_name(name).num_views() == 9


# ---------------------------------------------------------------------------
# search space validity
# ---------------------------------------------------------------------------

def test_space_structural_rejections():
    space = SearchSpace(schemes=("inl", "fl", "sl"),
                        topologies=("star(3)", "chain(3)"),
                        link_bits=(4, 32), wires=("dense", "packed"))
    keys = {p.key for p in space.points()}
    assert "inl/chain(3)/q4/packed/dfull" in keys
    assert "inl/star(3)/q32/packed/dfull" not in keys   # packed needs <= 16
    assert not any(k.startswith("fl/chain") or k.startswith("sl/chain")
                   for k in keys)                       # star-only schemes
    assert [k for k in keys if k.startswith("fl/")] == \
        ["fl/star(3)/q32/dense/dfull"]                  # fp32 weights only
    assert not any(k.startswith("sl/") and "/q4/" in k for k in keys)
    reasons = {p.key: r for p, r in space.excluded()}
    assert "star topology" in reasons["fl/chain(3)/q32/dense/dfull"]
    assert "fp32" in reasons["fl/star(3)/q4/dense/dfull"]


def test_cut_depth_only_for_hybrids():
    space = SearchSpace(schemes=("inl", "splitfed"), topologies=("star(3)",),
                        cut_depths=(None, 1))
    keys = {p.key for p in space.points()}
    assert keys == {"inl/star(3)/q32/dense/dfull",
                    "splitfed/star(3)/q32/dense/dfull",
                    "splitfed/star(3)/q32/dense/d1"}


def test_resolve_adapts_clients_and_noise():
    p = ConfigPoint("inl", "tree(2,2)", link_bits=8, wire="packed")
    cfg, topo = p.resolve(CFG)
    assert cfg.num_clients == 6 and topo is not None
    assert cfg.noise_stds == tuple(CFG.noise_stds[j % len(CFG.noise_stds)]
                                   for j in range(6))
    assert cfg.link_bits == 8
    star = ConfigPoint("inl", f"star({CFG.num_clients})")
    cfg2, topo2 = star.resolve(CFG)
    assert topo2 is None                     # default star = legacy path
    assert cfg2.noise_stds == CFG.noise_stds


# ---------------------------------------------------------------------------
# pricing + pruning
# ---------------------------------------------------------------------------

def _price(points):
    return price(points, CFG, batch_size=BATCH, train_n=CFG.dataset_size)


def test_wire_equivalence_prunes_to_dense_rep():
    priced = _price(SearchSpace(schemes=("inl",), topologies=("star(3)",),
                                link_bits=(4,),
                                wires=("dense", "packed")).points())
    by = {pp.key: pp for pp in priced}
    dense = by["inl/star(3)/q4/dense/dfull"]
    packed = by["inl/star(3)/q4/packed/dfull"]
    assert dense.status == CANDIDATE
    assert packed.status == PRUNED_WIRE and packed.stand_in == dense.key
    assert packed.round_bits == dense.round_bits   # width-only closed form
    assert packed.round_nbytes < dense.round_nbytes


def test_star_dominance_prunes_q32_graphs_only():
    priced = _price(merge_points(
        SearchSpace(schemes=("inl",), topologies=("star(3)", "chain(3)")),
        SearchSpace(schemes=("inl",), topologies=("star(3)", "chain(3)"),
                    link_bits=(4,), wires=("packed_duplex",))))
    by = {pp.key: pp for pp in priced}
    chain32 = by["inl/chain(3)/q32/dense/dfull"]
    assert chain32.status == PRUNED_STAR
    assert chain32.stand_in == "inl/star(3)/q32/dense/dfull"
    assert chain32.round_bits > by[chain32.stand_in].round_bits
    # narrow links re-quantize per hop — accuracy genuinely moves, so the
    # graph point must train
    assert by["inl/chain(3)/q4/packed_duplex/dfull"].status == CANDIDATE


def test_no_star_sibling_no_prune():
    priced = _price(SearchSpace(schemes=("inl",),
                                topologies=("chain(3)",)).points())
    assert priced[0].status == CANDIDATE     # nothing to stand in for it


def test_pricing_matches_meter_exactly():
    """Stage-1 price == the runner's metered ledgers, both sides sums of
    the same integer-valued charges — equality, not isclose."""
    pp = _price([ConfigPoint("inl", f"star({CFG.num_clients})")])[0]
    views, labels = fixture_data()
    meter = bandwidth.BandwidthMeter()
    curve = schemes.runner.run_scheme(
        "inl", views, labels, pp.cfg, epochs=1, batch_size=BATCH,
        eval_n=64, meter=meter, topology=pp.topology, wire=pp.point.wire)
    assert abs(meter.total_bits - pp.epoch_bits()) < 1.0
    assert abs(meter.measured_bytes - pp.epoch_nbytes()) < 1.0
    assert curve[-1].gbits == pytest.approx(pp.total_gbits(1))


def test_rounds_per_epoch_rule_is_shared():
    scheme = schemes.get("inl")
    n = CFG.dataset_size
    assert schemes.runner.rounds_per_epoch(scheme, CFG, n, BATCH) == \
        (n // BATCH) // scheme.batches_per_round(CFG)
    pp = _price([ConfigPoint("inl", f"star({CFG.num_clients})")])[0]
    assert pp.rounds_per_epoch == \
        schemes.runner.rounds_per_epoch(scheme, pp.cfg, n, BATCH)


# ---------------------------------------------------------------------------
# pareto
# ---------------------------------------------------------------------------

class P:
    def __init__(self, key, accuracy, gbits):
        self.key, self.accuracy, self.gbits = key, accuracy, gbits


def test_dominates_weak_both_strict_one():
    assert dominates(P("a", 0.9, 1.0), P("b", 0.8, 1.0))
    assert dominates(P("a", 0.9, 0.5), P("b", 0.9, 1.0))
    assert not dominates(P("a", 0.9, 1.0), P("b", 0.9, 1.0))   # exact tie
    assert not dominates(P("a", 0.9, 2.0), P("b", 0.8, 1.0))   # trade-off


def test_pareto_frontier_extraction():
    pts = [P("cheap", 0.5, 0.1), P("mid", 0.8, 1.0), P("best", 0.9, 5.0),
           P("dominated", 0.7, 2.0), P("dup-mid", 0.8, 1.0),
           P("worse-same-cost", 0.6, 1.0)]
    front = pareto_frontier(pts)
    keys = [p.key for p in front]
    assert keys == ["cheap", "mid", "dup-mid", "best"]
    for f in front:
        assert not any(dominates(q, f) for q in pts)


def test_best_under_budget():
    pts = [P("cheap", 0.5, 0.1), P("best", 0.9, 5.0)]
    assert best_under_budget(pts, 1.0).key == "cheap"
    assert best_under_budget(pts, 10.0).key == "best"
    assert best_under_budget(pts, 0.01) is None


# ---------------------------------------------------------------------------
# driver end-to-end (two tiny trains)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_run_search_end_to_end():
    base = dataclasses.replace(CFG, dataset_size=64)
    result = run_search(
        [ConfigPoint("inl", "star(3)"),
         ConfigPoint("inl", "star(3)", link_bits=4, wire="packed_duplex"),
         ConfigPoint("inl", "chain(3)")],
        base, epochs=1, batch_size=BATCH, eval_n=32, train_pruned=False,
        log=lambda *a: None)
    assert len(result.candidates()) == 2
    pruned = result.measured["inl/chain(3)/q32/dense/dfull"]
    assert not pruned.trained                # inherited from its stand-in
    assert pruned.accuracy == \
        result.measured["inl/star(3)/q32/dense/dfull"].accuracy
    assert pruned.gbits > result.measured[pruned.stand_in].gbits
    assert result.frontier                    # non-empty, candidates only
    for m in result.frontier:
        assert m.status == CANDIDATE and m.trained
