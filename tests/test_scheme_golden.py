"""Golden-metric regression: the exact loss/accuracy trajectories of every
registered scheme on the tiny deterministic fixture, pinned to checked-in
JSON (rtol 1e-4) — so a scheme/kernel refactor cannot silently change
training dynamics while the qualitative tests still pass.

Regenerate after an INTENDED change with

    REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_scheme_golden.py

and commit the updated tests/golden/scheme_metrics.json alongside the
change that explains it.  Trajectories are shared with the parity tests
via tests/_schemes_common.py (one compile per scheme per process).
"""
import json
import os
import pathlib

import numpy as np
import pytest
from _schemes_common import ROUNDS, trajectory

from repro.core import schemes

GOLDEN_PATH = pathlib.Path(__file__).parent / "golden" / "scheme_metrics.json"
RTOL = 1e-4

CASES = [("inl", False), ("fl", False), ("sl", False), ("inl", True),
         ("splitfed", False), ("hybrid", False)]


def _key(name, learned_prior):
    return f"{name}+learned_prior" if learned_prior else name


def _record(name, learned_prior):
    rec = trajectory(name, learned_prior=learned_prior)
    return {"losses": list(rec["losses"]),
            "final_accuracy": rec["final_accuracy"]}


def _regen():
    data = {_key(n, lp): _record(n, lp) for n, lp in CASES}
    GOLDEN_PATH.parent.mkdir(parents=True, exist_ok=True)
    GOLDEN_PATH.write_text(json.dumps(data, indent=2) + "\n")
    return data


@pytest.fixture(scope="module")
def golden():
    if os.environ.get("REGEN_GOLDEN"):
        return _regen()
    assert GOLDEN_PATH.exists(), \
        f"{GOLDEN_PATH} missing — run with REGEN_GOLDEN=1 to create it"
    return json.loads(GOLDEN_PATH.read_text())


@pytest.mark.parametrize("name,learned_prior", CASES,
                         ids=[_key(n, lp) for n, lp in CASES])
def test_trajectory_matches_golden(name, learned_prior, golden):
    want = golden[_key(name, learned_prior)]
    got = _record(name, learned_prior)
    assert len(got["losses"]) == ROUNDS
    np.testing.assert_allclose(got["losses"], want["losses"], rtol=RTOL,
                               err_msg=f"{name} loss trajectory drifted "
                                       "(REGEN_GOLDEN=1 if intended)")
    np.testing.assert_allclose(got["final_accuracy"],
                               want["final_accuracy"], rtol=RTOL, atol=1e-6)


def test_golden_covers_every_registered_scheme(golden):
    """A newly registered scheme must add itself to the golden record."""
    plain = {k for k in golden if "+" not in k}
    assert set(schemes.available()) <= plain, \
        "register the new scheme in CASES and regenerate the golden file"
