"""Substrate: optimizer, checkpoint, data pipeline, sharding rules,
roofline parsing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or deterministic fallback

from repro import checkpoint, optim
from repro.data import multiview, tokens
from repro.roofline import analysis as roofline


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_adamw_converges_quadratic():
    opt = optim.adamw(0.1, clip_norm=None)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_master_weights_bf16():
    opt = optim.adamw(1e-2)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert "master" in state and state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4,), 1e-3, jnp.bfloat16)}
    p2, s2 = opt.update(grads, state, params)
    assert p2["w"].dtype == jnp.bfloat16
    # master accumulates sub-bf16 updates
    assert float(jnp.abs(s2["master"]["w"] - 1.0).max()) > 0


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(optim.global_norm(clipped)), 1.0,
                               rtol=1e-5)


def test_warmup_cosine_schedule():
    sched = optim.warmup_cosine_schedule(1.0, 10, 100)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(sched(jnp.asarray(100))) < 0.11


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"a": {"b": jnp.arange(6).reshape(2, 3).astype(jnp.float32)},
            "c": [jnp.ones((4,), jnp.bfloat16), jnp.zeros((2,), jnp.int32)]}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 7, tree)
        template = jax.tree.map(jnp.zeros_like, tree)
        restored, step = checkpoint.restore(d, template)
        assert step == 7
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises():
    tree = {"w": jnp.ones((3,))}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, 1, tree)
        with pytest.raises(ValueError):
            checkpoint.restore(d, {"w": jnp.ones((4,))})
        with pytest.raises(ValueError):
            checkpoint.restore(d, {"w2": jnp.ones((3,))})


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_multiview_classes_separable():
    imgs, labels = multiview.make_base_dataset(400, seed=0)
    # nearest class-mean classifier on clean images must beat chance easily
    means = np.stack([imgs[labels == c].mean(axis=0) for c in range(10)])
    d = ((imgs[:, None] - means[None]) ** 2).sum(axis=(2, 3, 4))
    acc = (d.argmin(axis=1) == labels).mean()
    assert acc > 0.5, acc


def test_views_noise_ordering():
    imgs, _ = multiview.make_base_dataset(64, seed=0)
    views = multiview.make_views(imgs, (0.4, 1.0, 4.0))
    errs = [float(((views[j] - imgs) ** 2).mean()) for j in range(3)]
    assert errs[0] < errs[1] < errs[2]


def test_experiment_splits():
    imgs, labels = multiview.make_base_dataset(100, seed=0)
    views = multiview.make_views(imgs, (0.4, 1.0))
    s1 = multiview.split_experiment1(views, labels, 2)
    assert s1["inl"][0].shape[1] == 100
    assert sum(l.shape[0] for _, l in s1["fl"]) == 100
    s2 = multiview.split_experiment2(views, labels, 2)
    assert all(v.shape[0] == 100 for v, _ in s2["fl"])


def test_token_stream_learnable():
    toks = tokens.markov_stream(64, 4000, seed=1, noise=0.1)
    # the mode of next-token given current captures >= 50% transitions
    from collections import Counter, defaultdict
    nxt = defaultdict(Counter)
    for a, b in zip(toks[:-1], toks[1:]):
        nxt[a][b] += 1
    hit = sum(c.most_common(1)[0][1] for c in nxt.values())
    assert hit / (len(toks) - 1) > 0.5


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_param_specs_divisibility_guard():
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.launch.sharding import param_spec

    try:
        mesh = AbstractMesh((16, 16), ("data", "model"))      # jax >= 0.5
    except TypeError:
        mesh = AbstractMesh((("data", 16), ("model", 16)))    # jax 0.4.x

    class Key:
        def __init__(self, k):
            self.key = k

    leaf = jax.ShapeDtypeStruct((2048, 4096), jnp.bfloat16)
    spec = param_spec((Key("attn"), Key("wq"), Key("w")), leaf, mesh)
    assert spec == P("data", "model")
    # non-divisible output dim stays replicated on model
    leaf2 = jax.ShapeDtypeStruct((2048, 20), jnp.bfloat16)
    spec2 = param_spec((Key("attn"), Key("wq"), Key("w")), leaf2, mesh)
    assert spec2 == P("data", None)
    # moe experts on model, fsdp on d
    leaf3 = jax.ShapeDtypeStruct((4, 128, 2048, 64), jnp.bfloat16)
    spec3 = param_spec((Key("moe"), Key("wi")), leaf3, mesh)
    assert spec3 == P(None, "model", "data", None)
    # norms replicated
    leaf4 = jax.ShapeDtypeStruct((2048,), jnp.bfloat16)
    spec4 = param_spec((Key("attn_norm"), Key("scale")), leaf4, mesh)
    assert spec4 == P(None)


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %ag = bf16[2,1024,512]{2,1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[256,128]{1,0} all-reduce(%y), to_apply=%add
  %tuple = (bf16[64,64]{1,0}, bf16[64,64]{1,0}) all-to-all(%a, %b)
  %cp = f32[8,8]{1,0} collective-permute-start(%z)
  %cpd = f32[8,8]{1,0} collective-permute-done(%cp)
  %rs = bf16[4,4]{1,0} reduce-scatter(%w), dimensions={0}
"""


def test_collective_parser():
    got = roofline.collective_bytes(HLO_SAMPLE)
    assert got["all-gather"] == 2 * 1024 * 512 * 2
    assert got["all-reduce"] == 256 * 128 * 4
    assert got["all-to-all"] == 2 * 64 * 64 * 2
    assert got["collective-permute"] == 8 * 8 * 4     # -done not re-counted
    assert got["reduce-scatter"] == 4 * 4 * 2
    assert got["total"] == sum(v for k, v in got.items()
                               if k not in ("total",))


def test_roofline_terms_dominance():
    t = roofline.roofline_terms(1e15, 1e12, 1e9, 256)
    assert t["dominant"] == "compute"
    t = roofline.roofline_terms(1e12, 1e15, 1e9, 256)
    assert t["dominant"] == "memory"
    t = roofline.roofline_terms(1e10, 1e10, 1e13, 256)
    assert t["dominant"] == "collective"


def test_model_flops_modes():
    from repro.configs import INPUT_SHAPES, get_config
    cfg = get_config("llama3.2-1b")
    n = cfg.param_count()
    f_train = roofline.model_flops(cfg, INPUT_SHAPES["train_4k"])
    f_prefill = roofline.model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    f_decode = roofline.model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert f_train == 6 * n * 256 * 4096
    assert f_prefill == 2 * n * 32 * 32768
    assert f_decode == 2 * n * 128
    # MoE: active < total drives the roofline
    ds = get_config("deepseek-v2-236b")
    assert roofline.model_flops(ds, INPUT_SHAPES["train_4k"]) \
        < 6 * ds.param_count() * 256 * 4096
