"""Quickstart: the paper's in-network learning on the multi-view task.

Five edge nodes each observe a differently-noised view of the same image;
each runs its own conv encoder and ships only a 16-dim stochastic bottleneck
latent to the central node, which fuses them and classifies.  Training
optimises eq. (6) end-to-end; only activations/error vectors ever cross the
links.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.paper_inl import SMOKE as CFG
from repro.core import inl
from repro.data import multiview


def main():
    imgs, labels = multiview.make_base_dataset(512, seed=0)
    views = multiview.make_views(imgs, CFG.noise_stds)      # (J, n, 32,32,3)
    print(f"J={CFG.num_clients} nodes, views {views.shape}, "
          f"bottleneck {CFG.d_bottleneck}-d per node")

    params, state = inl.init(CFG, jax.random.PRNGKey(0))
    opt = optim.adam(2e-3)
    opt_state = opt.init(params)
    step = inl.make_train_step(CFG, opt)
    rng = jax.random.PRNGKey(1)

    bits = 0.0
    for epoch in range(4):
        for v, l in multiview.multiview_batches(views, labels, 64,
                                                seed=epoch):
            rng, sub = jax.random.split(rng)
            params, state, opt_state, m = step(
                params, state, opt_state, jnp.asarray(v), jnp.asarray(l),
                sub)
            bits += float(m["bits_sent"])
        acc = inl.evaluate(params, state, jnp.asarray(views),
                           jnp.asarray(labels))
        print(f"epoch {epoch}: loss={float(m['loss']):.3f} "
              f"acc={float(acc):.3f} rate={float(m['rate_mean']):.2f} nats "
              f"bandwidth={bits/1e6:.2f} Mbit")

    probs = inl.predict(params, state, jnp.asarray(views[:, :4]))
    print("soft predictions (first 4):", jnp.round(probs.max(-1), 3),
          "labels:", labels[:4])


if __name__ == "__main__":
    main()
