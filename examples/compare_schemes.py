"""Reproduce the paper's comparison (Figures 5/7, reduced scale): INL vs
federated vs split learning — accuracy per epoch and per Gbit exchanged.

    PYTHONPATH=src python examples/compare_schemes.py [--epochs 4]
"""
import argparse

from benchmarks import accuracy_curves


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--experiment", type=int, default=2, choices=[1, 2])
    args = ap.parse_args()

    views, labels, _ = accuracy_curves._data(args.experiment)
    results = {}
    for scheme, runner in (("INL", accuracy_curves.run_inl),
                           ("SL", accuracy_curves.run_sl),
                           ("FL", accuracy_curves.run_fl)):
        results[scheme] = runner(views, labels, args.epochs)

    print(f"\nExperiment {args.experiment} "
          f"(paper fig {5 if args.experiment == 1 else 7}):")
    print(f"{'epoch':>6} | " + " | ".join(
        f"{s:>5} acc / Gbit" for s in results))
    for i in range(args.epochs):
        row = f"{i+1:>6} | "
        row += " | ".join(
            f"{results[s][i][1]:.3f} / {results[s][i][2]:.4f}"
            for s in results)
        print(row)
    final = {s: r[-1] for s, r in results.items()}
    print("\nbandwidth-efficiency (final acc / Gbit):")
    for s, (ep, acc, gb) in final.items():
        print(f"  {s:4s}: {acc/max(gb, 1e-9):10.2f} acc/Gbit "
              f"(acc {acc:.3f}, {gb:.4f} Gbit)")
    print("\npaper's qualitative claim: INL >> SL > FL per bit; "
          "INL >= SL > FL in accuracy.")


if __name__ == "__main__":
    main()
