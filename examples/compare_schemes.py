"""Reproduce the paper's comparison (Figures 5/7, reduced scale): every
scheme in the unified registry — INL vs federated vs split learning vs
the hybrid schemes — accuracy per epoch and per Gbit exchanged, on one
shared runner and one fused cut-layer substrate.

    PYTHONPATH=src python examples/compare_schemes.py [--epochs 4]

--topology chain re-routes the exchange over a J-hop line (each relay
fuses the upstream latents with its own view — the follow-up paper's
multi-hop setting) and prints the per-edge bandwidth ledger.  Schemes
whose exchange has no multi-hop reading (FL's weight broadcast, SL's
single client->server boundary) are skipped with a one-line notice; pass
--strict to make a skip fail the run instead.
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from benchmarks.accuracy_curves import BATCH, CFG, _data  # noqa: E402
from repro.core import bandwidth, schemes                 # noqa: E402
from repro.core import topology as topology_lib           # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--experiment", type=int, default=2, choices=[1, 2])
    ap.add_argument("--schemes", default="",
                    help="comma list (default: every registered scheme)")
    ap.add_argument("--topology", default="star", choices=["star", "chain"],
                    help="exchange graph (star-only schemes are skipped "
                         "on chain with a notice)")
    ap.add_argument("--strict", action="store_true",
                    help="exit nonzero if any requested scheme had to be "
                         "skipped (star-only scheme on a multi-hop graph)")
    args = ap.parse_args()

    if args.schemes:
        names = tuple(s.strip() for s in args.schemes.split(",") if s.strip())
        unknown = set(names) - set(schemes.available())
        if unknown:
            ap.error(f"unknown scheme(s) {sorted(unknown)}; "
                     f"registered: {schemes.available()}")
    else:
        names = schemes.available()
    topo = None
    if args.topology == "chain":
        topo = topology_lib.chain(CFG.num_clients)
        print(f"multi-hop exchange: {topo.describe()}")

    views, labels = _data(args.experiment)
    results, meters, skipped = {}, {}, []
    for name in names:
        meter = bandwidth.BandwidthMeter()
        try:
            results[name] = schemes.runner.run_scheme(
                name, views, labels, CFG, epochs=args.epochs,
                batch_size=BATCH, topology=topo,
                **({"meter": meter} if topo else {}))
            meters[name] = meter
        except ValueError:
            # topology.require_star: the scheme's exchange has no
            # multi-hop reading — skip it, one line, no traceback
            print(f"scheme {name!r} requires a star topology — skipped "
                  f"on {args.topology}")
            skipped.append(name)

    print(f"\nExperiment {args.experiment} "
          f"(paper fig {5 if args.experiment == 1 else 7}):")
    print(f"{'epoch':>6} | " + " | ".join(
        f"{s:>5} acc / Gbit" for s in results))
    for i in range(args.epochs):
        row = f"{i+1:>6} | "
        row += " | ".join(
            f"{results[s][i].accuracy:.3f} / {results[s][i].gbits:.4f}"
            for s in results)
        print(row)
    print("\nbandwidth-efficiency (final acc / Gbit):")
    for s, curve in results.items():
        pt = curve[-1]
        print(f"  {s:4s}: {schemes.runner.efficiency(curve):10.2f} acc/Gbit "
              f"(acc {pt.accuracy:.3f}, {pt.gbits:.4f} Gbit)")
    if topo is not None:
        print("\nper-edge ledger (closed-form Gbit | measured Gbit):")
        for s in results:
            meter = meters[s]
            for edge in (e.key for e in topo.topo_edges()):
                print(f"  {s:8s} {edge:12s}: "
                      f"{meter.edge_bits[edge] / 1e9:.4f} | "
                      f"{meter.edge_measured_bytes[edge] * 8 / 1e9:.4f}")
    print("\npaper's qualitative claim: INL >> SL > FL per bit; "
          "INL >= SL > FL in accuracy.")
    if skipped and args.strict:
        print(f"--strict: {len(skipped)} scheme(s) skipped: {skipped}")
        sys.exit(1)


if __name__ == "__main__":
    main()
