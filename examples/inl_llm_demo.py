"""The paper's technique on an assigned architecture: llama3.2-1b (reduced)
split into J=2 edge encoders + fusion decoder, trained with the eq.-(6)
D-VIB loss over quantized bottleneck links.

    PYTHONPATH=src python examples/inl_llm_demo.py [--steps 30]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import get_smoke_config
from repro.core import inl_llm
from repro.data import tokens
from repro.models import transformer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--link-bits", type=int, default=8)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_smoke_config(args.arch), dtype="float32")
    pat = transformer.block_pattern(cfg)
    need = (cfg.inl.encoder_layers + 1) * len(pat) + cfg.moe.first_dense_layers
    if cfg.num_layers < need:
        cfg = dataclasses.replace(cfg, num_layers=need)
    cfg = dataclasses.replace(
        cfg, inl=dataclasses.replace(cfg.inl, link_bits=args.link_bits))

    params = inl_llm.init(cfg, jax.random.PRNGKey(0))
    opt = optim.adam(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(inl_llm.make_train_step(cfg, opt))
    rng = jax.random.PRNGKey(1)

    print(f"{cfg.name}: J={cfg.inl.num_nodes} encoder nodes x "
          f"{cfg.inl.encoder_layers} period(s), {cfg.inl.d_bottleneck}-d "
          f"bottleneck at {args.link_bits} bits/value")
    for i, batch in enumerate(tokens.lm_batches(cfg, 4, 64,
                                                steps=args.steps)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        rng, sub = jax.random.split(rng)
        params, opt_state, m = step(params, opt_state, batch, sub)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:3d}: loss={float(m['loss']):.3f} "
                  f"joint-CE={float(m['ce_joint']):.3f} "
                  f"rate={float(m['rate_mean']):.2f} nats "
                  f"link={int(m['bits_per_token'])} bits/token")


if __name__ == "__main__":
    main()
