"""End-to-end driver (deliverable b): train a ~100M-parameter model for a
few hundred steps on the synthetic token stream and show the loss dropping,
with checkpointing.

Default is a dense ~100M llama-family config (CPU-friendly matmuls; the
assigned archs are selectable with --arch, e.g. --arch xlstm-125m trains
the full 125M xLSTM, which is exact-recurrence-heavy and much slower on a
1-core CPU).

    PYTHONPATH=src python examples/train_llm.py [--steps 250] [--seq 64]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, optim
from repro.configs import get_config
from repro.configs.base import INLConfig, ModelConfig
from repro.data import tokens as token_data
from repro.launch import steps as steps_lib
from repro.models import zoo

# ~100M params, FFN-heavy with a small vocab so a few hundred CPU steps see
# enough visits per token for the loss to drop visibly.
DENSE_100M = ModelConfig(
    name="dense-100m", family="dense", num_layers=6, d_model=1024,
    num_heads=8, num_kv_heads=8, d_ff=4096, vocab_size=2048,
    tie_embeddings=True, dtype="float32",
    inl=INLConfig(num_nodes=2, d_bottleneck=512))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--arch", default="dense-100m")
    args = ap.parse_args()

    cfg = DENSE_100M if args.arch == "dense-100m" else get_config(args.arch)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n:,}")

    opt = optim.adamw(optim.warmup_cosine_schedule(
        1e-3, args.steps // 10 + 1, args.steps), weight_decay=0.1,
        clip_norm=1.0)
    opt_state = opt.init(params)
    step = jax.jit(steps_lib.make_train_step(cfg, opt))

    t0 = time.time()
    history = []
    for i, batch in enumerate(token_data.lm_batches(
            cfg, args.batch, args.seq, steps=args.steps)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step(params, opt_state, batch)
        if i % 25 == 0 or i == args.steps - 1:
            rec = {"step": i, "ce": round(float(m["ce"]), 4),
                   "wall_s": round(time.time() - t0, 1)}
            history.append(rec)
            print(json.dumps(rec), flush=True)
        if i and i % 100 == 0:
            checkpoint.save("ckpts/train_llm", i, params,
                            extra={"arch": cfg.name})
    checkpoint.save("ckpts/train_llm", args.steps, params,
                    extra={"arch": cfg.name})
    drop = history[0]["ce"] - history[-1]["ce"]
    print(f"CE dropped by {drop:.3f} nats over {args.steps} steps "
          f"({'OK' if drop > 0.1 else 'insufficient — increase steps'})")


if __name__ == "__main__":
    main()
